"""In-scan telemetry & trace subsystem tests (DESIGN.md §8).

Trace-off invariance is the load-bearing guarantee: with ``trace=None``
(the default) or ``TraceConfig(enabled=False)`` every protocol must
reproduce the committed fabric goldens bit-for-bit on BOTH backends —
the telemetry arrays and ops never enter the untraced program. Tracing
on must be pure observation (hypothesis property), the ledger must stay
bounded with an exact overflow count, and the strided series must agree
with the end-of-run aggregates exactly. The JSON satellites (SimResult
round-trip, bucketed_percentiles empty schema) are pinned here too.
"""
import json
from pathlib import Path

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st_

from repro.core import (SimConfig, FabricConfig, TraceConfig, SimTrace,
                        SweepSpec, simulate, run_sweep, make_messages)
from repro.core import telemetry
from repro.core.results import SimResult, bucketed_percentiles
from repro.core.telemetry import (EV_GRANT, EV_PREEMPT, EV_LOSS,
                                  EV_OVERFLOW, EV_RESEND, EV_TIMEOUT,
                                  EV_COMPLETE, EV_COLUMNS)

GOLDEN = Path(__file__).parent / "golden"
ALL_PROTOS = ["homa", "basic", "phost", "pias", "pfabric", "ndp"]
BACKENDS = ["reference", "pallas"]
OFF_SENTINELS = [None, TraceConfig(enabled=False)]


@pytest.fixture(scope="module")
def disabled():
    return json.loads((GOLDEN / "fabric_disabled.json").read_text())


@pytest.fixture(scope="module")
def enabled():
    return json.loads((GOLDEN / "fabric_enabled.json").read_text())


def _table(meta):
    return make_messages(meta["workload"], n_hosts=meta["n_hosts"],
                         load=meta["load"], n_messages=meta["n_messages"],
                         slot_bytes=meta["slot_bytes"], seed=meta["seed"])


def _cfg(meta, proto, *, fabric=None, backend="reference", trace=None):
    return SimConfig(protocol=proto, n_hosts=meta["n_hosts"],
                     max_slots=meta["max_slots"], ring_cap=meta["ring_cap"],
                     fabric=fabric, backend=backend, trace=trace)


def _traced_run(proto="homa", *, n_hosts=8, n_messages=120, max_slots=4000,
                trace=None, fabric=None, seed=0, load=0.6):
    tbl = make_messages("W2", n_hosts=n_hosts, load=load,
                        n_messages=n_messages, slot_bytes=256, seed=seed)
    cfg = SimConfig(n_hosts=n_hosts, protocol=proto, ring_cap=512,
                    max_slots=max_slots, fabric=fabric, trace=trace)
    return simulate(cfg, tbl)


# ------------------------------------------------ trace-off invariance ----

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("trace", OFF_SENTINELS,
                         ids=["trace=None", "enabled=False"])
@pytest.mark.parametrize("proto", ALL_PROTOS)
def test_trace_off_matches_disabled_golden(disabled, proto, trace, backend):
    """Acceptance: with tracing absent or disabled, every protocol on
    both backends reproduces the pre-telemetry golden bit-for-bit."""
    meta, want = disabled["meta"], disabled["protocols"][proto]
    r = simulate(_cfg(meta, proto, backend=backend, trace=trace),
                 _table(meta))
    assert [int(x) for x in r.completion] == want["completion"]
    assert [int(x) for x in r.q_max_bytes] == want["q_max_bytes"]
    assert r.trace is None and r.trace_summary is None


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("trace", OFF_SENTINELS,
                         ids=["trace=None", "enabled=False"])
@pytest.mark.parametrize("proto", ["homa", "pfabric"])
def test_trace_off_matches_enabled_golden(enabled, proto, trace, backend):
    """Same invariance through the fabric tier (TOR uplink state in the
    scan carry must not shift with telemetry compiled out)."""
    meta, want = enabled["meta"], enabled["protocols"][proto]
    fab = FabricConfig(racks=meta["racks"], oversub=meta["oversub"],
                       up_cap=meta["up_cap"])
    r = simulate(_cfg(meta, proto, fabric=fab, backend=backend,
                      trace=trace), _table(meta))
    assert [int(x) for x in r.completion] == want["completion"]
    assert [int(x) for x in r.tor_up_q_max_bytes] \
        == want["tor_up_q_max_bytes"]


@settings(max_examples=8, deadline=None)
@given(proto=st_.sampled_from(ALL_PROTOS),
       n_hosts=st_.sampled_from([4, 8]),
       racks=st_.sampled_from([0, 2]),
       stride=st_.sampled_from([1, 7, 64]),
       ledger_cap=st_.sampled_from([0, 8, 512]),
       seed=st_.integers(min_value=0, max_value=4))
def test_tracing_is_pure_observation(proto, n_hosts, racks, stride,
                                     ledger_cap, seed):
    """Property: for any protocol, topology, stride and ledger size,
    tracing never changes completion slots or slowdowns."""
    tbl = make_messages("W1", n_hosts=n_hosts, load=0.5, n_messages=40,
                        slot_bytes=256, seed=seed, max_bytes=2000)
    fab = FabricConfig(racks=racks, oversub=2.0) if racks else None
    base = dict(n_hosts=n_hosts, protocol=proto, fabric=fab,
                max_slots=3000, ring_cap=256)
    r0 = simulate(SimConfig(**base), tbl)
    r1 = simulate(SimConfig(**base, trace=TraceConfig(
        stride=stride, ledger_cap=ledger_cap)), tbl)
    np.testing.assert_array_equal(r0.completion, r1.completion)
    np.testing.assert_array_equal(r0.slowdown, r1.slowdown)


# ------------------------------------------------------ ledger capture ----

def test_ledger_records_all_completions_when_roomy():
    """With capacity to spare, the ledger holds exactly one COMPLETE row
    per finished message, values = elapsed slots, in slot order."""
    r = _traced_run(trace=TraceConfig(stride=32, ledger_cap=8192))
    tr = r.trace
    assert isinstance(tr, SimTrace)
    assert tr.events_dropped == 0
    comp = tr.events_of(EV_COMPLETE)
    assert comp.shape[0] == r.n_complete
    # each row's (slot, msg, value) must reconcile with SimResult
    done = {int(m): int(s) for m, s in zip(comp[:, 2], comp[:, 0])}
    for m, slot in done.items():
        assert int(r.completion[m]) == slot
        assert int(comp[comp[:, 2] == m, 4][0]) == int(r.elapsed[m])
    assert np.all(np.diff(tr.events[:, 0]) >= 0)        # slot-ordered
    assert tr.events.shape[1] == len(EV_COLUMNS)


def test_ledger_overflow_bounded_and_counted():
    """A tiny ledger stays at capacity and the overflow counter equals
    seen - kept exactly; the kept prefix is untouched by later events."""
    small = _traced_run(trace=TraceConfig(stride=32, ledger_cap=16))
    big = _traced_run(trace=TraceConfig(stride=32, ledger_cap=8192))
    ts, tb = small.trace, big.trace
    assert ts.n_events == 16
    assert ts.n_events_seen == tb.n_events_seen       # same run, same events
    assert ts.events_dropped == ts.n_events_seen - 16
    np.testing.assert_array_equal(ts.events, tb.events[:16])


def test_ledger_cap_zero_disables_ledger_keeps_series():
    r = _traced_run(trace=TraceConfig(stride=32, ledger_cap=0))
    tr = r.trace
    assert tr.n_events == 0 and tr.n_events_seen == 0
    assert tr.q_bytes.shape[0] == len(tr.sample_slots)


def test_fault_events_reach_the_ledger():
    """Loss, RESEND and timeout rows appear under injected uplink loss,
    and grant rows exist for a scheduled protocol."""
    fab = FabricConfig(racks=2, oversub=2.0, faults=dict(up_loss=0.05))
    r = _traced_run(n_hosts=8, fabric=fab, max_slots=12_000,
                    trace=TraceConfig(stride=64, ledger_cap=65536))
    tr = r.trace
    assert tr.events_of(EV_GRANT).shape[0] > 0
    assert tr.events_of(EV_LOSS)[:, 4].sum() == r.fault_lost_chunks
    assert tr.events_of(EV_RESEND).shape[0] \
        + tr.events_of(EV_TIMEOUT).shape[0] > 0


# -------------------------------------------------------- strided series --

def test_series_cumulative_counters_match_result_aggregates():
    """The final sample of each cumulative series must equal the
    end-of-run aggregate SimResult already reports — the strided series
    is exact, not approximate."""
    r = _traced_run(trace=TraceConfig(stride=16, ledger_cap=0))
    tr = r.trace
    # busy_frac aggregates pool all hosts x slots
    assert int(tr.busy_cum[-1]) == int(round(
        float(np.mean(r.busy_frac)) * 4000 * tr.n_hosts))
    np.testing.assert_array_equal(
        tr.prio_drained_cum_bytes[-1],
        np.asarray(r.prio_drained_bytes))
    # windowed rates sum back to the cumulative total
    assert np.isclose(tr.busy_frac().sum(),
                      tr.busy_cum[-1] / (tr.n_hosts * 16))


def test_series_shapes_and_sample_slots():
    """ceil(max_slots/stride) rows; windows end at stride-1 boundaries
    with the last (partial) window ending at max_slots-1."""
    r = _traced_run(max_slots=1000,
                    trace=TraceConfig(stride=300, ledger_cap=0))
    tr = r.trace
    assert tr.sample_slots.tolist() == [299, 599, 899, 999]
    assert tr.q_bytes.shape == (4, 8)
    assert tr.grant_out_bytes.shape == (4, 8)
    widths = np.diff(tr.sample_slots, prepend=-1)
    assert widths.tolist() == [300, 300, 300, 100]


def test_fabric_series_present_only_with_fabric():
    fab = FabricConfig(racks=2, oversub=2.0)
    r_fab = _traced_run(fabric=fab, trace=TraceConfig(stride=64))
    r_one = _traced_run(trace=TraceConfig(stride=64))
    assert r_fab.trace.up_q_bytes is not None
    assert r_fab.trace.prio_usage("up").shape[1] == 8
    assert r_one.trace.up_q_bytes is None
    with pytest.raises(ValueError):
        r_one.trace.prio_usage("up")


# ---------------------------------------------------- sweeps & reduction --

def test_run_sweep_reduces_trace_to_scalars():
    """vmapped sweeps keep only SimTrace.reduce() scalars per run — no
    (N, T, H) histories — and stay bit-identical to solo runs."""
    tables = [make_messages("W2", n_hosts=8, load=0.5, n_messages=60,
                            slot_bytes=256, seed=s) for s in range(2)]
    cfg = SimConfig(n_hosts=8, protocol="homa", ring_cap=256,
                    max_slots=2000,
                    trace=TraceConfig(stride=32, ledger_cap=256))
    solo = [simulate(cfg, t) for t in tables]
    swept = run_sweep(cfg, SweepSpec(tables=tables))
    for a, b in zip(solo, swept):
        np.testing.assert_array_equal(a.completion, b.completion)
        assert b.trace is None
        assert b.trace_summary["n_events_seen"] == a.trace.n_events_seen
        assert b.trace_summary["q_peak_bytes"] \
            == int(a.trace.q_bytes.max())


# ------------------------------------------------------------ exporters ----

def test_perfetto_export_valid_and_complete(tmp_path):
    r = _traced_run(trace=TraceConfig(stride=64, ledger_cap=2048))
    fp = tmp_path / "trace.json"
    doc = r.trace.to_perfetto(fp)
    loaded = json.loads(fp.read_text())
    assert loaded["traceEvents"] == doc["traceEvents"]
    phases = {e["ph"] for e in loaded["traceEvents"]}
    assert {"M", "C", "i", "X"} <= phases
    n_complete_slices = sum(1 for e in loaded["traceEvents"]
                            if e["ph"] == "X")
    assert n_complete_slices == r.trace.events_of(EV_COMPLETE).shape[0]
    assert loaded["otherData"]["stride"] == 64


def test_timeseries_json_is_json_safe():
    fab = FabricConfig(racks=2, oversub=2.0, faults=dict(up_loss=0.02))
    r = _traced_run(fabric=fab, max_slots=6000,
                    trace=TraceConfig(stride=64, ledger_cap=128))
    doc = r.trace.to_timeseries_json()
    s = json.dumps(doc)                       # must not raise
    back = json.loads(s)
    assert back["events"]["columns"] == list(EV_COLUMNS)
    assert back["events"]["dropped"] == r.trace.events_dropped
    assert "up_q_bytes" in back


# ------------------------------------------------- JSON satellites --------

def test_bucketed_percentiles_empty_schema_has_count():
    """Satellite: the empty return carries the same keys as the
    non-empty one (the bench cache iterates count unconditionally)."""
    out = bucketed_percentiles(np.array([]), np.array([]),
                               np.array([], bool))
    assert set(out) == {"sizes", "p", "median", "count"}
    assert out["count"] == []
    # no-finished-messages case shares the schema too
    out2 = bucketed_percentiles(np.array([100, 200]),
                                np.array([np.nan, np.nan]),
                                np.array([False, False]))
    assert set(out2) == {"sizes", "p", "median", "count"}


def test_simresult_summary_json_safe_with_all_optionals():
    """Satellite: summary() must json.dumps cleanly with fabric, fault
    and trace fields populated (numpy scalars, arrays, NaN)."""
    fab = FabricConfig(racks=2, oversub=2.0, faults=dict(up_loss=0.02))
    r = _traced_run(fabric=fab, n_messages=60, max_slots=1500,
                    trace=TraceConfig(stride=64, ledger_cap=64))
    s = json.dumps(json.loads(r.to_json()))   # round-trips as strict JSON
    assert "trace" in json.loads(s)


def test_simresult_full_json_round_trip():
    """Satellite: to_json(full=True) -> from_json reconstructs every
    array field bit-for-bit, including NaN slowdowns for incomplete
    messages and the fault/fabric arrays."""
    fab = FabricConfig(racks=2, oversub=2.0, faults=dict(up_loss=0.02))
    r = _traced_run(fabric=fab, n_messages=80, max_slots=900,
                    trace=TraceConfig(stride=128, ledger_cap=64))
    assert r.n_complete < r.n_messages        # NaN slowdowns exercised
    back = SimResult.from_json(r.to_json(full=True))
    np.testing.assert_array_equal(back.completion, r.completion)
    np.testing.assert_array_equal(back.done, r.done)
    np.testing.assert_allclose(back.slowdown, r.slowdown)   # NaN == NaN
    np.testing.assert_array_equal(back.retx_chunks, r.retx_chunks)
    np.testing.assert_array_equal(back.tor_up_q_max_bytes,
                                  r.tor_up_q_max_bytes)
    assert back.alloc.cutoffs == r.alloc.cutoffs
    assert back.trace_summary == r.trace_summary
    assert back.protocol == r.protocol


def test_from_json_rejects_foreign_documents():
    with pytest.raises(ValueError):
        SimResult.from_json(json.dumps({"completion": [1, 2]}))


# ------------------------------------------------------- config plumbing --

def test_trace_config_validation():
    with pytest.raises(ValueError):
        SimConfig(n_hosts=4, trace=TraceConfig(stride=0))
    with pytest.raises(ValueError):
        SimConfig(n_hosts=4, trace=TraceConfig(ledger_cap=-1))
    with pytest.raises(ValueError):
        SimConfig(n_hosts=4, trace=TraceConfig(wallclock_repeats=0))


def test_trace_config_coerced_from_dict():
    cfg = SimConfig(n_hosts=4, trace=dict(stride=8, ledger_cap=32))
    assert isinstance(cfg.trace, TraceConfig)
    assert cfg.trace.stride == 8 and cfg.trace_on


def test_wallclock_reports_aot_split():
    """wallclock=True runs the scan through the AOT path and attaches
    the trace/compile/execute split — with capture on or off."""
    r_on = _traced_run(n_messages=30, max_slots=500, trace=TraceConfig(
        stride=64, ledger_cap=32, wallclock=True))
    t = r_on.trace.timings
    assert set(t) >= {"trace_s", "compile_s", "execute_s"}
    r_off = _traced_run(n_messages=30, max_slots=500, trace=TraceConfig(
        enabled=False, wallclock=True, wallclock_repeats=2))
    t2 = r_off.trace_summary["timings"]
    assert r_off.trace is None and t2["execute_repeats"] == 2
