"""Integration test of the multi-pod dry-run machinery on one small cell
(the full sweep is `python -m repro.launch.dryrun --all`; here we prove the
512-device mesh construction + lower + compile + artifact parsing path in a
subprocess, since jax locks device count at init)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
ENV = {**os.environ, "PYTHONPATH": str(REPO / "src")}


@pytest.mark.parametrize("multipod", [False, True])
def test_dryrun_small_cell(multipod, tmp_path):
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch",
           "whisper-small", "--shape", "decode_32k", "--force"]
    if multipod:
        cmd.append("--multi-pod")
    r = subprocess.run(cmd, capture_output=True, text=True, env=ENV,
                       cwd=REPO, timeout=1500)
    assert r.returncode == 0, r.stderr[-3000:]
    mesh = "2x16x16" if multipod else "16x16"
    art = REPO / "artifacts" / "dryrun" / \
        f"whisper-small__decode_32k__{mesh}.json"
    d = json.loads(art.read_text())
    assert d["status"] == "ok"
    assert d["n_chips"] == (512 if multipod else 256)
    assert d["cost"]["flops"] > 0
    assert d["memory"]["argument_size_in_bytes"] > 0
    # per-device bytes stay far below one full copy of params + caches
    # (whisper decode_32k: ~200 MB params + ~25 GB global KV caches)
    assert d["memory"]["argument_size_in_bytes"] < 4e9


def test_collective_parse():
    from repro.launch.dryrun import parse_collective_bytes
    hlo = """
  %all-reduce.1 = f32[128,256]{1,0} all-reduce(%x), replica_groups={}
  %ag = bf16[64]{0} all-gather(%y), dimensions={0}
  %nope = f32[4]{0} add(%a, %b)
  ROOT %r = (f32[8]{0}) tuple(%z)
"""
    out = parse_collective_bytes(hlo)
    assert out["all-reduce"]["count"] == 1
    assert out["all-reduce"]["bytes"] == 128 * 256 * 4
    assert out["all-gather"]["count"] == 1
    assert out["all-gather"]["bytes"] == 64 * 2
    assert out["total_bytes"] == 128 * 256 * 4 + 128


def test_input_specs_all_cells_build():
    """input_specs (ShapeDtypeStructs + shardings) must build for every
    non-skipped cell without touching devices — subprocess with 512 virtual
    devices, all cells in one go (cheap: no lowering)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax
from repro.configs import ARCH_NAMES
from repro.configs.base import SHAPES, cell_is_skipped
from repro.launch.mesh import make_production_mesh
from repro.launch.inputs import input_specs
for mp in (False, True):
    mesh = make_production_mesh(multi_pod=mp)
    for a in ARCH_NAMES:
        for s in SHAPES:
            if cell_is_skipped(a, s):
                continue
            specs = input_specs(a, s, mesh)
            n = len(jax.tree.leaves(specs))
            assert n > 3, (a, s)
print("SPECS_OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=ENV, cwd=REPO, timeout=1200)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "SPECS_OK" in r.stdout
