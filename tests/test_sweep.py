"""SweepSpec engine tests (DESIGN.md §9): streaming-percentile accuracy
(hypothesis property + simulator-level tolerance), chunked-scan and
device-sharding bit-identity for every protocol, grouping, and the
8-virtual-device recipe (subprocess, ``XLA_FLAGS``)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (SimConfig, SweepSpec, StreamSpec, SweepStats,
                        TraceConfig, simulate, run_sweep, make_messages)
from repro.core import sweep as sweep_mod

ALL_PROTOS = ["homa", "basic", "phost", "pias", "pfabric", "ndp"]
SMALL = dict(n_hosts=4, max_slots=1500, ring_cap=256)


def _tables(n=2, n_messages=100, seed0=0, n_hosts=4):
    return [make_messages("W2", n_hosts=n_hosts, load=0.6,
                          n_messages=n_messages, slot_bytes=256, seed=seed0 + s)
            for s in range(n)]


# ------------------------------------------------------------ StreamSpec --

def test_streamspec_validation():
    with pytest.raises(ValueError, match="n_buckets"):
        StreamSpec(n_buckets=1)
    with pytest.raises(ValueError, match="max_slowdown"):
        StreamSpec(max_slowdown=1.0)
    with pytest.raises(ValueError, match="small_bytes"):
        StreamSpec(small_bytes=999)          # not a size-bucket edge
    with pytest.raises(ValueError, match="increasing"):
        StreamSpec(size_edges=(1000, 256))
    with pytest.raises(ValueError, match="warmup_frac"):
        StreamSpec(warmup_frac=1.0)
    s = StreamSpec()
    assert s.rel_err_bound < 0.01            # defaults: ~0.9%
    assert hash(s)                           # must ride the jit cache key


def test_shard_knob_validation():
    assert sweep_mod.resolve_devices(False) == 1
    assert sweep_mod.resolve_devices(1) == 1
    with pytest.raises(ValueError, match="devices"):
        sweep_mod.resolve_devices(10_000)


def test_group_runs_preserves_order():
    groups = sweep_mod.group_runs([(100, 4), (80, 4), (100, 4), (80, 2)])
    assert groups == {(100, 4): [0, 2], (80, 4): [1], (80, 2): [3]}


# ------------------------------------------- streaming estimator (host) --

@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000), st.integers(5, 400), st.floats(1.0, 40.0))
def test_streaming_percentile_property(seed, n, spread):
    """Over ragged slowdown distributions, the streaming estimate is
    within ``rel_err_bound`` of the same-rank (lower) order statistic —
    the estimator's documented contract — for every quantile."""
    rng = np.random.default_rng(seed)
    # ragged mix: point mass at 1.0 + lognormal tail, occasionally huge
    sd = 1.0 + rng.lognormal(0.0, 1.0, n) * (spread - 1.0) / 40.0
    sd[rng.random(n) < 0.3] = 1.0
    stream = StreamSpec()
    hist = sweep_mod.streaming_hist(sd, stream)
    assert hist.sum() == n
    s_sorted = np.sort(sd.astype(np.float32))
    for q in (10.0, 50.0, 90.0, 99.0):
        est = sweep_mod.percentile_from_hist(hist, stream, q)
        lower = float(s_sorted[int(np.floor(q / 100 * (n - 1)))])
        assert abs(est - lower) / lower <= stream.rel_err_bound + 1e-6, \
            (q, est, lower)


def test_streaming_percentile_empty_and_overflow():
    stream = StreamSpec(n_buckets=64, max_slowdown=100.0)
    assert sweep_mod.percentile_from_hist(
        np.zeros(64, np.int64), stream, 99) is None
    # samples beyond max_slowdown land in (and report) the last bucket
    hist = sweep_mod.streaming_hist([1e9], stream)
    assert hist[-1] == 1
    est = sweep_mod.percentile_from_hist(hist, stream, 50)
    assert est >= 100.0 / stream.bucket_ratio


# -------------------------------------------------- simulator tolerance --

def test_streaming_matches_exact_within_tolerance():
    """The acceptance gate: streaming sweep percentiles vs the exact
    (non-streaming) run, within the documented bound against the lower
    order statistic and a looser envelope vs numpy's interpolation."""
    cfg = SimConfig(protocol="homa", n_hosts=4, max_slots=6000,
                    ring_cap=512)
    tbls = _tables(n=2, n_messages=600)
    exact = run_sweep(cfg, SweepSpec(tables=tbls))
    stream = StreamSpec()
    stats = run_sweep(cfg, SweepSpec(tables=tbls, streaming=stream,
                                     chunk_slots=512))
    for stt, ref in zip(stats, exact):
        assert isinstance(stt, SweepStats)
        assert stt.n_complete == ref.n_complete
        sd = np.sort(ref.slowdown[ref.done].astype(np.float32))
        n = len(sd)
        for q in (50.0, 90.0, 99.0):
            est = stt.percentile(q)
            lower = float(sd[int(np.floor(q / 100 * (n - 1)))])
            assert abs(est - lower) / lower \
                <= stream.rel_err_bound + 1e-6, (q, est, lower)
            # vs interpolated numpy: the provable envelope is the
            # estimator bound plus the bracketing order-statistic gap
            # (interp lies between sorted[k] and sorted[k+1])
            interp = float(np.percentile(sd, q))
            upper = float(sd[min(int(np.ceil(q / 100 * (n - 1))), n - 1)])
            envelope = stream.rel_err_bound * lower + (upper - lower)
            assert abs(est - interp) <= envelope + 1e-6, \
                (q, est, interp, envelope)
        # device histogram == host mirror on the exact run's slowdowns
        np.testing.assert_array_equal(
            stt.hist.sum(axis=0),
            sweep_mod.streaming_hist(ref.slowdown[ref.done], stream))
        # small-message split is exact (small_bytes is a bucket edge)
        small = ref.done & (ref.size_bytes < stream.small_bytes)
        assert stt.hist[:2].sum() == int(small.sum())
        s = stt.summary()
        assert s["p99_small"] is not None
        assert s["streaming"]["rel_err_bound"] == round(
            stream.rel_err_bound, 6)


def test_streaming_warmup_trims_head():
    cfg = SimConfig(protocol="homa", **SMALL)
    (tbl,) = _tables(n=1)
    stream = StreamSpec(warmup_frac=0.5)
    stt = run_sweep(cfg, SweepSpec(tables=(tbl,), streaming=stream))[0]
    ref = simulate(cfg, tbl)
    counted = np.zeros(len(tbl.size), bool)
    counted[len(tbl.size) // 2:] = True
    assert stt.n_counted == int((ref.done & counted).sum())
    assert stt.n_complete == ref.n_complete      # completions still total


# ------------------------------------------------- bit-identity matrix --

@pytest.mark.parametrize("proto", ALL_PROTOS)
def test_chunked_scan_bit_identical(proto):
    """chunk_slots nests the scan but replays the same step sequence —
    results must be bit-identical, including a non-dividing remainder
    chunk (1500 % 400 != 0)."""
    cfg = SimConfig(protocol=proto, **SMALL)
    tbls = _tables(n=2)
    base = run_sweep(cfg, SweepSpec(tables=tbls))
    for chunk in (400, 1500, 5000):
        got = run_sweep(cfg, SweepSpec(tables=tbls, chunk_slots=chunk))
        for a, b in zip(base, got):
            np.testing.assert_array_equal(a.completion, b.completion)
            np.testing.assert_array_equal(a.q_max_bytes, b.q_max_bytes)
            np.testing.assert_array_equal(a.prio_drained_bytes,
                                          b.prio_drained_bytes)
            assert a.lost_chunks == b.lost_chunks


@pytest.mark.parametrize("proto", ALL_PROTOS)
def test_sharded_path_bit_identical(proto):
    """shard=True routes through the shard_map runner (padded to a
    device multiple) — bit-identical to the default vmap path."""
    cfg = SimConfig(protocol=proto, **SMALL)
    tbls = _tables(n=3)          # odd count: exercises padding
    base = run_sweep(cfg, SweepSpec(tables=tbls))
    got = run_sweep(cfg, SweepSpec(tables=tbls, shard=True,
                                   chunk_slots=500))
    for a, b in zip(base, got):
        np.testing.assert_array_equal(a.completion, b.completion)
        np.testing.assert_array_equal(a.slowdown[a.done],
                                      b.slowdown[b.done])


def test_chunked_trace_bit_identical():
    """Telemetry rows are indexed by global slot, so strided series and
    ledger survive chunking unchanged; streaming sweeps reduce the trace
    device-side to the same peaks SimTrace.reduce() reports."""
    cfg = SimConfig(protocol="homa", trace=TraceConfig(stride=16,
                                                       ledger_cap=256),
                    **SMALL)
    (tbl,) = _tables(n=1)
    ref = simulate(cfg, tbl)
    chunked = run_sweep(cfg, SweepSpec(tables=(tbl,), chunk_slots=333))[0]
    assert chunked.trace_summary["q_peak_bytes"] \
        == ref.trace_summary["q_peak_bytes"]
    assert chunked.trace_summary["n_events_seen"] \
        == ref.trace_summary["n_events_seen"]
    stt = run_sweep(cfg, SweepSpec(tables=(tbl,), chunk_slots=333,
                                   streaming=True))[0]
    ts = stt.trace_summary
    assert ts["q_peak_bytes"] == ref.trace_summary["q_peak_bytes"]
    assert ts["grant_out_peak_bytes"] \
        == ref.trace_summary["grant_out_peak_bytes"]
    assert ts["n_events_seen"] == ref.trace_summary["n_events_seen"]
    assert ts["events_dropped"] == ref.trace_summary["events_dropped"]


# --------------------------------------------------- multi-device (sub) --

def test_eight_virtual_devices_bit_identical():
    """The README recipe end-to-end: force 8 host devices in a fresh
    interpreter (XLA_FLAGS must precede jax import), shard a sweep over
    them, and require bit-identity — exact completions AND streaming
    histograms — with the single-device run."""
    prog = textwrap.dedent("""
        import numpy as np, jax
        from repro.core import SimConfig, SweepSpec, make_messages
        from repro.core.sim import run_sweep
        assert len(jax.devices()) == 8, jax.devices()
        cfg = SimConfig(n_hosts=4, max_slots=1200, ring_cap=256,
                        protocol="homa")
        tbls = [make_messages("W1", n_hosts=4, load=0.6, n_messages=80,
                              slot_bytes=256, seed=s) for s in range(6)]
        one = run_sweep(cfg, SweepSpec(tables=tbls))
        many = run_sweep(cfg, SweepSpec(tables=tbls, shard=8,
                                        chunk_slots=300))
        for a, b in zip(one, many):
            np.testing.assert_array_equal(a.completion, b.completion)
        s1 = run_sweep(cfg, SweepSpec(tables=tbls, streaming=True))
        s8 = run_sweep(cfg, SweepSpec(tables=tbls, streaming=True,
                                      shard=8, chunk_slots=300))
        for a, b in zip(s1, s8):
            np.testing.assert_array_equal(a.hist, b.hist)
        print("OK")
    """)
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": "src"}
    r = subprocess.run([sys.executable, "-c", prog], env=env,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
