"""Substrate tests: checkpointing (atomic/restart/elastic), data determinism,
Homa gradient sync (vs naive psum, on 8 virtual devices via subprocess),
serving scheduler invariants, fault-tolerant restart."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

REPO = Path(__file__).resolve().parents[1]
ENV = {**os.environ, "PYTHONPATH": str(REPO / "src")}


def run_py(code: str, *, devices: int | None = None, timeout=600):
    env = dict(ENV)
    if devices:
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env, cwd=REPO)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


# ---------------------------------------------------------- checkpointing --

def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint.store import CheckpointStore
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16)},
            "step": jnp.asarray(7)}
    store = CheckpointStore(tmp_path, keep=2, async_save=False)
    store.save(7, tree)
    restored, step = store.restore(tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_keep_k_and_atomicity(tmp_path):
    from repro.checkpoint.store import CheckpointStore
    store = CheckpointStore(tmp_path, keep=2, async_save=False)
    tree = {"x": jnp.zeros(4)}
    for s in (1, 2, 3, 4):
        store.save(s, tree)
    assert store.steps() == [3, 4]
    # a partial (uncommitted) checkpoint is ignored
    bad = tmp_path / "step_99"
    bad.mkdir()
    (bad / "meta.json").write_text("{}")
    assert store.latest_step() == 4


def test_checkpoint_elastic_restore_new_sharding(tmp_path):
    """Same checkpoint restores onto a different device layout."""
    out = run_py(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint.store import CheckpointStore
        tree = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
        store = CheckpointStore(r"{tmp_path}", async_save=False)
        store.save(1, tree)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        sh = {{"w": NamedSharding(mesh, P("data", "model"))}}
        restored, _ = store.restore(tree, shardings=sh)
        assert restored["w"].sharding.num_devices == 8
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.arange(64).reshape(8, 8))
        print("ELASTIC_OK")
    """, devices=8)
    assert "ELASTIC_OK" in out


def test_crash_restart_resumes(tmp_path):
    """Simulated preemption at step 12, restart resumes from checkpoint 10
    and reaches the same final step with finite loss."""
    args = ["-m", "repro.launch.train", "--arch", "mamba2-130m", "--smoke",
            "--steps", "20", "--seq-len", "32", "--batch", "4",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "5",
            "--log-every", "1"]
    r1 = subprocess.run([sys.executable] + args + ["--crash-at", "12"],
                        capture_output=True, text=True, env=ENV, cwd=REPO,
                        timeout=900)
    assert r1.returncode == 17, r1.stderr[-2000:]
    assert "simulated preemption" in r1.stdout
    r2 = subprocess.run([sys.executable] + args + ["--resume"],
                        capture_output=True, text=True, env=ENV, cwd=REPO,
                        timeout=900)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed from step 10" in r2.stdout
    assert "step 20" in r2.stdout


# ------------------------------------------------------------------ data ---

def test_data_determinism_and_sharding():
    from repro.data.pipeline import DataConfig, SyntheticLM
    a = SyntheticLM(DataConfig(32, 8, 100, seed=3)).batch(5)
    b = SyntheticLM(DataConfig(32, 8, 100, seed=3)).batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # different hosts/steps differ
    c = SyntheticLM(DataConfig(32, 8, 100, seed=3, n_hosts=2,
                               host_id=1)).batch(5)
    assert not np.array_equal(a["tokens"][:4], c["tokens"])
    d = SyntheticLM(DataConfig(32, 8, 100, seed=3)).batch(6)
    assert not np.array_equal(a["tokens"], d["tokens"])


# ------------------------------------------------- homa gradient sync ------

def test_homa_allreduce_matches_naive_8dev():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distrib import homa_collectives as HC
        mesh = jax.make_mesh((8,), ("data",))
        grads = {"a": jnp.arange(999, dtype=jnp.float32).reshape(3, 333),
                 "b": {"c": jnp.ones((17,), jnp.float32) * 2}}
        cfg = HC.SyncConfig(chunk_bytes=256, overcommit=3)

        @jax.jit
        @jax.shard_map(mesh=mesh, in_specs=(P(),), out_specs=(P(), P()),
                       check_vma=False)
        def both(g):
            h, _ = HC.homa_allreduce(g, "data", cfg)
            n = HC.naive_allreduce(g, "data")
            return h, n

        h, n = both(grads)
        for x, y in zip(jax.tree.leaves(h), jax.tree.leaves(n)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=1e-6)
        print("SYNC_OK")
    """, devices=8)
    assert "SYNC_OK" in out


def test_homa_allreduce_int8_compression_8dev():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distrib import homa_collectives as HC
        mesh = jax.make_mesh((8,), ("data",))
        key = jax.random.key(0)
        g = {"w": jax.random.normal(key, (64, 64))}
        cfg = HC.SyncConfig(chunk_bytes=1024, compress="int8")
        err0 = {"w": jnp.zeros((64 * 64,), jnp.float32)}

        @jax.jit
        @jax.shard_map(mesh=mesh, in_specs=(P(), P()),
                       out_specs=(P(), P()), check_vma=False)
        def sync(g, e):
            out, e2 = HC.homa_allreduce(g, "data", cfg, e)
            return out, e2

        out, err = sync(g, err0)
        exact = g["w"]
        got = out["w"]
        # int8 quantization: relative error bounded by ~1/127 per max-scale
        scale = float(jnp.max(jnp.abs(exact)))
        err_abs = float(jnp.max(jnp.abs(got - exact)))
        assert err_abs <= scale / 127 * 1.5 + 1e-6, (err_abs, scale)
        # error feedback holds the residual
        assert float(jnp.max(jnp.abs(err["w"]))) > 0
        print("COMPRESS_OK")
    """, devices=8)
    assert "COMPRESS_OK" in out


def test_chunk_plan_srpt_order():
    from repro.distrib.homa_collectives import SyncConfig, chunk_plan
    shapes = [((1000,), jnp.float32), ((10,), jnp.float32),
              ((100000,), jnp.float32)]
    plan = chunk_plan(shapes, SyncConfig(chunk_bytes=4000, srpt=True))
    # first chunk must be the smallest tensor (SRPT), big tensor's chunks
    # have descending remaining -> its last chunk sorts earlier than first
    assert plan[0].leaf == 1
    rema = [c.remaining for c in plan if c.leaf == 2]
    assert rema == sorted(rema)
    # coverage is exact and non-overlapping
    for leaf, n in ((0, 1000), (1, 10), (2, 100000)):
        segs = sorted((c.start, c.size) for c in plan if c.leaf == leaf)
        pos = 0
        for s, z in segs:
            assert s == pos
            pos += z
        assert pos == n


# ------------------------------------------------------- serving sched -----

def _mk(rid, size, t):
    from repro.serving.scheduler import Request
    return Request(rid=rid, prompt_len=4, max_new_tokens=size, arrival=t)


def test_scheduler_srpt_order_and_fast_path():
    from repro.serving.scheduler import HomaScheduler, SchedulerConfig
    s = HomaScheduler(SchedulerConfig(batch_size=2, overcommit=1,
                                      unsched_limit=4))
    s.submit(_mk(0, 100, 0.0))
    s.submit(_mk(1, 50, 0.1))
    s.submit(_mk(2, 3, 0.2))      # small: unscheduled fast path
    batch = s.select_batch()
    ids = [r.rid for r in batch]
    assert ids[0] == 2            # shortest first (SRPT)
    assert len(batch) == 2


def test_scheduler_completes_all_and_overcommit_refills():
    from repro.serving.scheduler import HomaScheduler, SchedulerConfig
    rng = np.random.default_rng(0)
    s = HomaScheduler(SchedulerConfig(batch_size=4, overcommit=3))
    for i in range(40):
        s.submit(_mk(i, int(rng.integers(1, 30)), i * 0.01))
    t = 1.0
    for _ in range(2000):
        if not s.active and not s.queue:
            break
        s.step(lambda batch: [r.remaining <= 1 for r in batch], t)
        t += 1.0
    assert len(s.finished) == 40
    # active set never exceeded batch+overcommit
    assert all(r.finish_time is not None for r in s.finished)


def test_scheduler_srpt_beats_fifo_mean_slowdown():
    from repro.serving.scheduler import HomaScheduler, SchedulerConfig
    rng = np.random.default_rng(1)
    sizes = [int(x) for x in rng.integers(1, 60, size=60)]

    def run(srpt):
        s = HomaScheduler(SchedulerConfig(batch_size=2, overcommit=2,
                                          srpt=srpt))
        for i, z in enumerate(sizes):
            s.submit(_mk(i, z, 0.0))
        t = 0.0
        for _ in range(20000):
            if not s.active and not s.queue:
                break
            s.step(lambda batch: [r.remaining <= 1 for r in batch], t)
            t += 1.0
        assert len(s.finished) == len(sizes)
        return float(np.mean(s.slowdowns()))

    assert run(True) < run(False)
