"""WorkloadSpec unification tests (+ the FabricConfig.with_* moves).

``WorkloadSpec`` is now the single generation recipe behind
``make_messages`` and the scenario generators; the public functions are
thin wrappers, so every pair (wrapper, spec.build) must be bit-identical
— the RNG draw order is part of the contract. Scenario determinism and
``merge_tables`` conservation are pinned for hotspot and shuffle
(incast's are covered in test_protocols.py), parameterized over seeds.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (SimConfig, FabricConfig, FaultConfig, SweepSpec,
                        WorkloadSpec, run_sweep, make_messages)
from repro.core import scenarios
from repro.core.scenarios import (hotspot, incast, shuffle, merge_tables,
                                  lossy_fabric, uplink_failure,
                                  tor_failure)

SEEDS = [3, 11]


def _eq(a, b):
    for f in ("src", "dst", "size", "arrival_slot"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f))
    assert (a.workload, a.load, a.slot_bytes) \
        == (b.workload, b.load, b.slot_bytes)


# ----------------------------------------------------------- validation ----

def test_spec_validation():
    with pytest.raises(ValueError, match="kind"):
        WorkloadSpec(kind="uniform")
    with pytest.raises(ValueError, match="workload"):
        WorkloadSpec(kind="poisson", load=0.5)
    with pytest.raises(ValueError, match="load"):
        WorkloadSpec(kind="hotspot", workload="W2")
    with pytest.raises(ValueError, match="fan_in"):
        WorkloadSpec(kind="incast", burst_bytes=1000)
    with pytest.raises(ValueError, match="bytes_per_pair"):
        WorkloadSpec(kind="shuffle")
    ws = WorkloadSpec(workload="W1", load=0.5, incast=[4, 2000, 500])
    assert ws.incast == (4, 2000, 500)       # normalized to tuple
    assert ws.with_seed(7).seed == 7 and ws.seed == 0


# --------------------------------------------------- wrapper equivalence ---

@pytest.mark.parametrize("seed", SEEDS)
def test_make_messages_is_spec_build(seed):
    a = make_messages("W2", n_hosts=8, load=0.6, n_messages=300,
                      slot_bytes=256, seed=seed, max_bytes=100_000,
                      incast=(4, 2000, 500))
    b = WorkloadSpec(workload="W2", load=0.6, n_messages=300, seed=seed,
                     max_bytes=100_000, incast=(4, 2000, 500)).build(
                         n_hosts=8, slot_bytes=256)
    _eq(a, b)


@pytest.mark.parametrize("seed", SEEDS)
def test_scenarios_are_spec_build(seed):
    _eq(incast(5, 20_000, n_hosts=8, n_bursts=3, seed=seed,
               background="W1", background_load=0.2, n_background=100),
        WorkloadSpec(kind="incast", fan_in=5, burst_bytes=20_000,
                     n_bursts=3, seed=seed, background="W1",
                     background_load=0.2, n_background=100).build(
                         n_hosts=8))
    _eq(hotspot("W2", n_hosts=8, load=0.5, n_messages=200, seed=seed,
                hot_fraction=0.6, n_hot=2),
        WorkloadSpec(kind="hotspot", workload="W2", load=0.5,
                     n_messages=200, seed=seed, hot_fraction=0.6,
                     n_hot=2).build(n_hosts=8))
    _eq(shuffle(n_hosts=8, bytes_per_pair=5000, spread_slots=400,
                seed=seed),
        WorkloadSpec(kind="shuffle", bytes_per_pair=5000,
                     spread_slots=400, seed=seed).build(n_hosts=8))


# ------------------------------------------- determinism (hotspot/shuffle) -

@pytest.mark.parametrize("seed", SEEDS)
def test_hotspot_deterministic_and_skewed(seed):
    a = hotspot("W2", n_hosts=8, load=0.5, n_messages=400, seed=seed,
                hot_fraction=0.7, n_hot=2)
    b = hotspot("W2", n_hosts=8, load=0.5, n_messages=400, seed=seed,
                hot_fraction=0.7, n_hot=2)
    _eq(a, b)
    c = hotspot("W2", n_hosts=8, load=0.5, n_messages=400, seed=seed + 1,
                hot_fraction=0.7, n_hot=2)
    assert not np.array_equal(a.dst, c.dst)
    assert (a.src != a.dst).all()
    # the hot set dominates destinations
    hot_share = np.isin(a.dst, [0, 1]).mean()
    assert hot_share > 0.5, hot_share


@pytest.mark.parametrize("seed", SEEDS)
def test_shuffle_deterministic_every_pair_once(seed):
    a = shuffle(n_hosts=6, bytes_per_pair=4000, spread_slots=300,
                seed=seed)
    b = shuffle(n_hosts=6, bytes_per_pair=4000, spread_slots=300,
                seed=seed)
    _eq(a, b)
    c = shuffle(n_hosts=6, bytes_per_pair=4000, spread_slots=300,
                seed=seed + 1)
    assert not np.array_equal(a.src, c.src)
    assert (a.src != a.dst).all()
    pairs = set(zip(a.src.tolist(), a.dst.tolist()))
    assert len(pairs) == len(a.src) == 6 * 5    # every ordered pair once
    assert (np.diff(a.arrival_slot) >= 0).all()


# ------------------------------------------- merge_tables conservation -----

@pytest.mark.parametrize("seed", SEEDS)
def test_merge_conserves_hotspot_and_shuffle(seed):
    a = hotspot("W3", n_hosts=8, load=0.4, n_messages=200, seed=seed)
    b = shuffle(n_hosts=8, bytes_per_pair=3000, spread_slots=500,
                seed=seed)
    m = merge_tables(a, b, workload="mix", load=0.4)
    assert len(m.src) == len(a.src) + len(b.src)
    # multiset conservation of every (src, dst, size, arrival) row
    rows = lambda t: sorted(zip(t.src.tolist(), t.dst.tolist(),   # noqa: E731
                                t.size.tolist(),
                                t.arrival_slot.tolist()))
    assert rows(m) == sorted(rows(a) + rows(b))
    assert (np.diff(m.arrival_slot) >= 0).all()  # re-sorted by arrival
    with pytest.raises(ValueError, match="slot sizes"):
        merge_tables(a, shuffle(n_hosts=8, bytes_per_pair=3000,
                                slot_bytes=512), workload="x", load=0.1)


# ------------------------------------------------ SweepSpec integration ----

def test_sweep_spec_accepts_workload_spec():
    ws = WorkloadSpec(kind="hotspot", workload="W2", load=0.5,
                      n_messages=120, n_hot=1)
    cfg = SimConfig(protocol="homa", n_hosts=4, max_slots=2500,
                    ring_cap=512)
    spec = SweepSpec(workload=ws, seeds=(3, 11))
    # each seed re-seeds the spec; results match sequential simulate
    from repro.core import simulate
    swe = run_sweep(cfg, spec)
    for seed, r in zip((3, 11), swe):
        tbl = ws.with_seed(seed).build(n_hosts=4, slot_bytes=256)
        np.testing.assert_array_equal(
            simulate(cfg, tbl).completion, r.completion)
    with pytest.raises(ValueError, match="WorkloadSpec"):
        SweepSpec(workload=ws, seeds=(0,), load=0.5)


def test_bench_sweep_point_accepts_spec(tmp_path, monkeypatch):
    """benchmarks.common.sim_sweep takes `spec` points directly, and the
    optional key joins the cache identity only when present."""
    from benchmarks import common
    monkeypatch.setattr(common, "ART", tmp_path)
    ws = dict(kind="shuffle", bytes_per_pair=2000, spread_slots=300)
    out = common.sim_sweep([dict(spec=ws)], protocol="homa", n_hosts=6,
                           max_slots=4000, ring_cap=512)
    assert out[0]["completion_rate"] == 1.0
    assert out[0]["params"]["spec"]["kind"] == "shuffle"
    with pytest.raises(ValueError, match="exactly one form"):
        common.sim_sweep([dict(spec=ws, workload="W1", load=0.5)],
                         protocol="homa")
    # a plain point's cache key must NOT contain the new optional axes
    keyd, _ = common._point_key(workload="W1", protocol="homa", load=0.5,
                                seed=0, overcommit=None, alloc=None,
                                unsched_limit_bytes=None, params={})
    assert "spec" not in keyd and "host" not in keyd


# ------------------------------------------- FabricConfig.with_* moves -----

def test_fabric_with_methods_match_legacy_helpers():
    fab = FabricConfig(racks=4, oversub=2.0)
    assert fab.with_lossy(up_loss=0.02) == lossy_fabric(fab, up_loss=0.02)
    assert fab.with_uplink_failure(uplink=1, start=100, end=500) \
        == uplink_failure(fab, uplink=1, start=100, end=500)
    assert fab.with_tor_failure(rack=2, start=50, end=90) \
        == tor_failure(fab, rack=2, start=50, end=90)
    # chaining accumulates windows on one FaultConfig
    chained = fab.with_lossy(up_loss=0.01) \
        .with_uplink_failure(uplink=0, start=10, end=20) \
        .with_uplink_failure(uplink=3, start=30, end=40)
    assert chained.faults.up_loss == 0.01
    assert chained.faults.link_fail == ((0, 10, 20), (3, 30, 40))
    assert isinstance(chained.faults, FaultConfig)
    with pytest.raises(ValueError, match="enabled fabric"):
        FabricConfig().with_lossy(up_loss=0.01)
    assert scenarios.__all__.count("lossy_fabric") == 1
