"""Host/NIC-stage tests (DESIGN.md §10).

The two contracts under test:

1. **Bit-identity when off.** ``host=None`` and the ``ideal`` preset
   (all costs zero) are structurally skipped, so the scan reproduces
   BOTH committed goldens bit-for-bit for every protocol — the host
   stage can never perturb a host-free run.
2. **Physics when on.** TX token bucket: sustained rate 1/cost with a
   ``tx_queue_cap``-deep cold burst; batching amortizes the interrupt
   cost; the RX FIFO serializes per-chunk service, delays ``recv`` (and
   therefore grants AND completions), and backpressures the downlink
   when full. Chunk conservation extends with the ring occupancy, and
   everything composes with fabric/faults/sweeps/chunked scans.
"""
import dataclasses
import json
from pathlib import Path

import numpy as np
import pytest

from repro.core import (SimConfig, FabricConfig, HostConfig, HostModel,
                        SweepSpec, TraceConfig, host_preset,
                        register_host_model, simulate, run_sweep,
                        make_messages)
from repro.core.hostmodel import (QSCALE, HOST_PRESETS, as_host_config,
                                  get_host_model)
from repro.core.workloads import MessageTable

GOLDEN = Path(__file__).parent / "golden"
ALL_PROTOS = ["homa", "basic", "phost", "pias", "pfabric", "ndp"]
SMALL = dict(n_hosts=4, max_slots=2500, ring_cap=512)


def _one_message(chunks: int, n_hosts: int = 2) -> MessageTable:
    """One message of ``chunks`` slots, host 0 -> 1, arriving at 0."""
    return MessageTable(np.array([0], np.int32), np.array([1], np.int32),
                        np.array([chunks * 256], np.int64),
                        np.array([0], np.int32), "single", 0.1, 256)


def _completion_slot(cfg, tbl) -> int:
    r = simulate(cfg, tbl)
    assert r.completion_rate == 1.0
    return int(r.completion.max())


# ---------------------------------------------- bit-identity when off ----

def _golden_assert(r, want, fabric: bool):
    assert [int(x) for x in r.completion] == want["completion"]
    assert r.lost_chunks == want["lost_chunks"]
    assert [int(x) for x in r.q_max_bytes] == want["q_max_bytes"]
    assert [int(x) for x in r.prio_drained_bytes] \
        == want["prio_drained_bytes"]
    if fabric:
        assert [int(x) for x in r.tor_up_q_max_bytes] \
            == want["tor_up_q_max_bytes"]
        assert r.tor_up_lost_chunks == want["tor_up_lost_chunks"]


@pytest.mark.parametrize("host", [None, "ideal",
                                  {"tx_cost_slots": 0.0}])
@pytest.mark.parametrize("proto", ALL_PROTOS)
def test_ideal_host_matches_disabled_golden(proto, host):
    """host=None, the ideal preset, and an explicit all-zero config all
    reproduce the fabric-disabled golden bit-for-bit (acceptance)."""
    g = json.loads((GOLDEN / "fabric_disabled.json").read_text())
    meta, want = g["meta"], g["protocols"][proto]
    tbl = make_messages(meta["workload"], n_hosts=meta["n_hosts"],
                        load=meta["load"], n_messages=meta["n_messages"],
                        slot_bytes=meta["slot_bytes"], seed=meta["seed"])
    cfg = SimConfig(protocol=proto, n_hosts=meta["n_hosts"],
                    max_slots=meta["max_slots"],
                    ring_cap=meta["ring_cap"], host=host)
    assert not cfg.host_on
    _golden_assert(simulate(cfg, tbl), want, fabric=False)


@pytest.mark.parametrize("host", [None, "ideal"])
@pytest.mark.parametrize("proto", ALL_PROTOS)
def test_ideal_host_matches_enabled_golden(proto, host):
    g = json.loads((GOLDEN / "fabric_enabled.json").read_text())
    meta, want = g["meta"], g["protocols"][proto]
    tbl = make_messages(meta["workload"], n_hosts=meta["n_hosts"],
                        load=meta["load"], n_messages=meta["n_messages"],
                        slot_bytes=meta["slot_bytes"], seed=meta["seed"])
    cfg = SimConfig(protocol=proto, n_hosts=meta["n_hosts"],
                    max_slots=meta["max_slots"], ring_cap=meta["ring_cap"],
                    fabric=FabricConfig(racks=meta["racks"],
                                        oversub=meta["oversub"],
                                        up_cap=meta["up_cap"]),
                    host=host)
    _golden_assert(simulate(cfg, tbl), want, fabric=True)


# --------------------------------------------------- TX-side physics ----

def test_tx_cost_throttles_sustained_rate():
    """tx_cost_slots=2 halves the sustained send rate: one long message
    takes ~2x the slots of the host-free run."""
    tbl = _one_message(200)
    base = _completion_slot(
        SimConfig(protocol="homa", n_hosts=2, max_slots=2000,
                  ring_cap=512), tbl)
    slow = _completion_slot(
        SimConfig(protocol="homa", n_hosts=2, max_slots=2000, ring_cap=512,
                  host=HostConfig(tx_cost_slots=2.0)), tbl)
    assert 1.8 * base < slow < 2.3 * base, (base, slow)


def test_tx_queue_cap_lets_cold_burst_through():
    """The bucket starts full (TX ring pre-fill): with a deep ring a
    short message goes out at line rate despite a high per-chunk cost;
    with a depth-1 ring the same message pays the cost per chunk."""
    tbl = _one_message(16)
    mk = lambda cap: SimConfig(              # noqa: E731
        protocol="homa", n_hosts=2, max_slots=800, ring_cap=512,
        host=HostConfig(tx_cost_slots=4.0, tx_queue_cap=cap))
    deep = _completion_slot(mk(32), tbl)
    shallow = _completion_slot(mk(1), tbl)
    base = _completion_slot(SimConfig(protocol="homa", n_hosts=2,
                                      max_slots=800, ring_cap=512), tbl)
    assert deep <= base + 2, (deep, base)        # burst absorbed
    assert shallow >= 4 * 15, (shallow, base)    # pays ~4 slots/chunk
    assert shallow > 2 * deep, (shallow, deep)


def test_tx_batching_amortizes_interrupt_cost():
    """(cost 1, +8 every 8th chunk) sustains ~2 slots/chunk — the same
    as a flat cost of 2 — and strictly beats paying 8 on every chunk."""
    tbl = _one_message(160)
    batched = _completion_slot(
        SimConfig(protocol="homa", n_hosts=2, max_slots=4000, ring_cap=512,
                  host=HostConfig(tx_cost_slots=1.0, tx_batch=8,
                                  tx_batch_cost_slots=8.0,
                                  tx_queue_cap=8)), tbl)
    flat = _completion_slot(
        SimConfig(protocol="homa", n_hosts=2, max_slots=4000, ring_cap=512,
                  host=HostConfig(tx_cost_slots=2.0)), tbl)
    every = _completion_slot(
        SimConfig(protocol="homa", n_hosts=2, max_slots=4000, ring_cap=512,
                  host=HostConfig(tx_cost_slots=1.0, tx_batch=1,
                                  tx_batch_cost_slots=8.0)), tbl)
    assert 0.8 * flat < batched < 1.2 * flat, (batched, flat)
    assert batched < 0.5 * every, (batched, every)


# --------------------------------------------------- RX-side physics ----

def test_rx_cost_monotonically_delays_completion():
    tbl = _one_message(100)
    done = []
    for cost in (0.0, 0.5, 2.0, 4.0):
        host = HostConfig(rx_cost_slots=cost) if cost else None
        done.append(_completion_slot(
            SimConfig(protocol="homa", n_hosts=2, max_slots=4000,
                      ring_cap=512, host=host), tbl))
    assert done == sorted(done), done
    assert done[-1] > 3.5 * done[0], done       # 4 slots/chunk serialized


def test_rx_ring_backpressures_downlink():
    """A tiny RX ring with slow service must stall the downlink (the
    chunk stays queued in the network) and record the stall slots."""
    tbl = _one_message(100)
    cfg = SimConfig(protocol="homa", n_hosts=2, max_slots=4000,
                    ring_cap=512,
                    host=HostConfig(rx_cost_slots=4.0, rx_queue_cap=4))
    r = simulate(cfg, tbl)
    assert r.completion_rate == 1.0
    assert int(r.host_rx_q_max_chunks.max()) == 4      # pinned at cap
    assert float(r.host_rx_stall_frac.max()) > 0.0
    assert r.summary()["host"]["rx_stall_frac"] > 0.0


def test_preset_latency_ordering():
    """ideal <= kernel_bypass < kernel_stack on the same workload."""
    tbl = make_messages("W2", n_hosts=4, load=0.4, n_messages=150,
                        slot_bytes=256, seed=0, max_bytes=65_536)
    p50 = {}
    for preset in ("ideal", "kernel_bypass", "kernel_stack"):
        cfg = SimConfig(protocol="homa", n_hosts=4, max_slots=20_000,
                        ring_cap=2048, host=preset)
        r = simulate(cfg, tbl)
        assert r.completion_rate == 1.0, preset
        p50[preset] = r.summary()["p50_all"]
    assert p50["ideal"] <= p50["kernel_bypass"] < p50["kernel_stack"], p50


# ------------------------------------------------------- conservation ----

@pytest.mark.parametrize("proto", ["homa", "basic", "ndp"])
def test_conservation_with_host_ring(proto):
    """sent == recv + downlink ring + RX ring occupancy (+ lost): the
    host FIFO is a real buffer in the chunk-conservation ledger."""
    tbl = make_messages("W3", n_hosts=6, load=0.6, n_messages=200,
                        slot_bytes=256, seed=3)
    cfg = SimConfig(protocol=proto, n_hosts=6, max_slots=4000,
                    ring_cap=512, host="kernel_stack")
    r = simulate(cfg, tbl, return_state=True)
    st = r.state
    rx_ring = int((st["h_rx_tail"] - st["h_rx_head"]).sum())
    assert int(st["recv"].sum()) + int(st["r_valid"].sum()) + rx_ring \
        + int(st["lost"]) == int(st["sent"].sum())


def test_conservation_with_host_and_fabric():
    tbl = make_messages("W3", n_hosts=12, load=0.6, n_messages=200,
                        slot_bytes=256, seed=3)
    cfg = SimConfig(protocol="homa", n_hosts=12, max_slots=6000,
                    ring_cap=512,
                    fabric=FabricConfig(racks=3, oversub=2.0),
                    host="kernel_bypass")
    r = simulate(cfg, tbl, return_state=True)
    st = r.state
    rx_ring = int((st["h_rx_tail"] - st["h_rx_head"]).sum())
    assert int(st["recv"].sum()) + int(st["r_valid"].sum()) \
        + int(st["u_valid"].sum()) + rx_ring + int(st["lost"]) \
        + int(st["u_lost"]) == int(st["sent"].sum())


# ------------------------------------------------------- composition ----

def test_host_composes_with_faults_and_recovers():
    tbl = make_messages("W2", n_hosts=8, load=0.5, n_messages=150,
                        slot_bytes=256, seed=1)
    fab = FabricConfig(racks=2, oversub=2.0).with_lossy(up_loss=0.01)
    cfg = SimConfig(protocol="homa", n_hosts=8, max_slots=20_000,
                    ring_cap=512, fabric=fab, host="kernel_bypass")
    r = simulate(cfg, tbl)
    assert r.completion_rate == 1.0
    assert r.summary()["faults"]["retx_chunks"] > 0
    assert r.host["rx_cost_slots"] == 0.5


def test_sweep_with_host_bit_identical_to_sequential():
    tables = [make_messages("W2", n_hosts=4, load=0.5, n_messages=100,
                            slot_bytes=256, seed=s) for s in range(3)]
    cfg = SimConfig(protocol="homa", host="kernel_stack", **SMALL)
    seq = [simulate(cfg, t) for t in tables]
    swe = run_sweep(cfg, SweepSpec(tables=tables))
    for a, b in zip(seq, swe):
        np.testing.assert_array_equal(a.completion, b.completion)
        np.testing.assert_array_equal(a.host_tx_busy_frac,
                                      b.host_tx_busy_frac)
        np.testing.assert_array_equal(a.host_rx_q_max_chunks,
                                      b.host_rx_q_max_chunks)


def test_chunked_scan_with_host_bit_identical():
    tbl = make_messages("W2", n_hosts=4, load=0.5, n_messages=100,
                        slot_bytes=256, seed=0)
    cfg = SimConfig(protocol="homa", host="kernel_stack", **SMALL)
    flat = run_sweep(cfg, SweepSpec(tables=[tbl]))[0]
    chunked = run_sweep(cfg, SweepSpec(tables=[tbl], chunk_slots=500))[0]
    np.testing.assert_array_equal(flat.completion, chunked.completion)
    np.testing.assert_array_equal(flat.host_tx_busy_frac,
                                  chunked.host_tx_busy_frac)


def test_streaming_sweep_carries_host_stats():
    cfg = SimConfig(protocol="homa", host="kernel_stack", **SMALL)
    spec = SweepSpec(workload="W2", load=0.5, seeds=(0, 1),
                     n_messages=100, streaming=True, chunk_slots=500)
    stats = run_sweep(cfg, spec)
    for s in stats:
        assert s.host_tx_busy_frac is not None \
            and 0 < s.host_tx_busy_frac < 1
        assert s.host_rx_q_max_chunks > 0
        d = s.summary()["host"]
        assert set(d) >= {"tx_busy_frac", "tx_defer_frac",
                          "rx_stall_frac", "rx_q_max_chunks"}


def test_trace_captures_host_rx_backlog():
    tbl = make_messages("W2", n_hosts=4, load=0.5, n_messages=100,
                        slot_bytes=256, seed=0)
    cfg = SimConfig(protocol="homa", host="kernel_stack",
                    trace=TraceConfig(enabled=True, stride=32), **SMALL)
    r = simulate(cfg, tbl)
    tr = r.trace
    assert tr.host_rx_q_chunks is not None
    assert tr.host_rx_q_chunks.shape[1] == 4
    peak = tr.reduce()["host_rx_q_peak_chunks"]
    assert peak == int(tr.host_rx_q_chunks.max()) > 0
    assert "host_rx_q_chunks" in tr.to_timeseries_json()
    # untraced hosts don't grow a series
    cfg2 = SimConfig(protocol="homa",
                     trace=TraceConfig(enabled=True, stride=32), **SMALL)
    assert simulate(cfg2, tbl).trace.host_rx_q_chunks is None


# --------------------------------------------- config + interface API ----

def test_host_config_normalization_and_result_echo():
    assert as_host_config(None) is None
    assert as_host_config("kernel_stack") == HOST_PRESETS["kernel_stack"]
    hc = as_host_config({"tx_cost_slots": 1.5, "rx_queue_cap": 32})
    assert hc.tx_cost_q == int(1.5 * QSCALE) and hc.rx_queue_cap == 32
    with pytest.raises(TypeError, match="HostConfig"):
        as_host_config(42)
    with pytest.raises(ValueError, match="preset"):
        SimConfig(host="not-a-preset")
    with pytest.raises(ValueError, match="tx_cost_slots"):
        SimConfig(host={"tx_cost_slots": -1.0})
    with pytest.raises(ValueError, match="rx_queue_cap"):
        SimConfig(host={"rx_queue_cap": 0})
    with pytest.raises(ValueError, match="unknown host model"):
        SimConfig(host={"model": "fpga"})
    # structural gates
    assert not SimConfig(host="ideal").host_on
    assert SimConfig(host="kernel_stack").host_tx_on
    assert not SimConfig(host={"rx_cost_slots": 1.0}).host_tx_on
    assert SimConfig(host={"rx_cost_slots": 1.0}).host_rx_on
    # round-trip: the result echoes the resolved config
    tbl = _one_message(10)
    r = simulate(SimConfig(protocol="homa", n_hosts=2, max_slots=400,
                           ring_cap=128, host="kernel_bypass"), tbl)
    assert HostConfig(**r.host) == HOST_PRESETS["kernel_bypass"]
    assert json.loads(r.to_json())["host"]["tx_cost_slots"] == 0.25


def test_host_model_interface_is_enforced():
    """abc enforcement: a model missing any hook cannot instantiate,
    and the registry only takes HostModel instances."""

    class Incomplete(HostModel):
        name = "incomplete"

        def init_state(self, cfg, M):
            return {}

    with pytest.raises(TypeError, match="abstract"):
        Incomplete()
    with pytest.raises(TypeError, match="HostModel instance"):
        register_host_model(object())
    with pytest.raises(ValueError, match="registered"):
        get_host_model("nope")
    assert host_preset("kernel_stack").tx_batch == 8
    with pytest.raises(ValueError, match="preset"):
        host_preset("nope")


def test_custom_host_model_pluggable():
    """A registered alternative model routes the scan through its own
    hooks — the interface seam is real, not cpu-only."""
    import jax.numpy as jnp
    from repro.core.protocols import I32
    from repro.core.hostmodel import _HOST_MODELS
    cpu = get_host_model("cpu")

    class DoubleCost(type(cpu)):
        """cpu model but every TX chunk charges twice the configured
        cost: observable as ~2x the completion time."""
        name = "double"

        def host_tx(self, cfg, st, want, now):
            hc = cfg.host
            budget = jnp.minimum(st["h_tx_budget_q"] + QSCALE,
                                 2 * hc.tx_burst_q)
            charge = jnp.full_like(budget, 2 * hc.tx_cost_q)
            ok = budget >= charge
            sent = want & ok
            spend = jnp.where(sent, charge, 0)
            return sent, {**st, "h_tx_budget_q": budget - spend,
                          "h_tx_work_q": st["h_tx_work_q"] + spend,
                          "h_tx_defer": st["h_tx_defer"]
                          + (want & ~ok).astype(I32)}

    register_host_model(DoubleCost())
    try:
        tbl = _one_message(100)
        single = _completion_slot(
            SimConfig(protocol="homa", n_hosts=2, max_slots=4000,
                      ring_cap=512,
                      host=HostConfig(tx_cost_slots=1.0)), tbl)
        double = _completion_slot(
            SimConfig(protocol="homa", n_hosts=2, max_slots=4000,
                      ring_cap=512,
                      host=HostConfig(model="double",
                                      tx_cost_slots=1.0)), tbl)
        assert 1.7 * single < double < 2.3 * single, (single, double)
    finally:
        del _HOST_MODELS["double"]
