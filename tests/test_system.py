"""End-to-end behaviour tests for the paper's system: the full Homa stack
(workload -> priority allocation -> simulation -> SRPT outcomes) plus the
training stack smoke (config -> data -> step -> checkpoint)."""
import numpy as np

from repro.core.sim import SimConfig, simulate
from repro.core.workloads import make_messages


def test_end_to_end_homa_pipeline():
    """Full pipeline: synthesize W2, allocate priorities from its CDF,
    simulate at 70% load, and verify the paper's qualitative outcome —
    small messages see near-ideal latency while the system stays lossless
    and conserves bytes."""
    tbl = make_messages("W2", n_hosts=6, load=0.7, n_messages=800,
                        slot_bytes=256, seed=11)
    cfg = SimConfig(n_hosts=6, protocol="homa", max_slots=40_000,
                    ring_cap=2048)
    res = simulate(cfg, tbl, return_state=True)
    # allocation reflects the workload's byte-weighted CDF (our W2
    # synthesis is heavier-tailed than the paper's — see EXPERIMENTS notes —
    # so it earns fewer unscheduled levels than the paper's ~6)
    assert 1 <= res.alloc.n_unsched <= 7
    # lossless
    assert res.lost_chunks == 0
    # conservation
    s = res.state
    assert int(s["recv"].sum()) + int(s["r_valid"].sum()) \
        == int(s["sent"].sum())
    # small-message tail near ideal
    ok = res.done & (res.size_bytes < 1000)
    assert ok.sum() > 50
    p99 = np.percentile(res.slowdown[ok], 99)
    assert p99 < 3.5, p99
    med = np.median(res.slowdown[res.done])
    assert med < 1.5, med
