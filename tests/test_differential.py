"""Cross-backend differential fuzz harness (DESIGN.md §11).

``simulate()`` must be BIT-identical across ``reference`` / ``pallas`` /
``pallas_fused`` for every configuration the simulator accepts — the
fused mega-kernel reorders reductions across kernel launches, and this
harness is what makes that safe. Two layers:

  * a fixed matrix of hand-picked corner configurations (protocol ×
    fabric × host preset × faults × ragged shapes) that always runs;
  * a hypothesis-driven fuzzer over random ``SimConfig``s that runs
    wherever hypothesis is installed (CI; the conftest stub skips it
    elsewhere), shrinking failures and printing the offender as a
    reproducible ``SimConfig``/``make_messages`` literal.

Every failure message contains a copy-pasteable repro, e.g.::

    SimConfig(protocol='phost', n_hosts=6, max_slots=400, ring_cap=100,
              overcommit=2, fabric=FabricConfig(racks=2, oversub=2.0,
              up_cap=64), host='kernel_stack', backend='pallas_fused')
    make_messages('W2', n_hosts=6, load=0.8, n_messages=40,
                  slot_bytes=256, seed=17)
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import SimConfig, FabricConfig, simulate, make_messages
from repro.core.fabric import FaultConfig

PROTOCOLS = ["homa", "basic", "phost", "pias", "pfabric", "ndp"]
BACKENDS = ["reference", "pallas", "pallas_fused"]

# fields every backend pair must agree on bit-for-bit
FIELDS = ["completion", "q_max_bytes", "prio_drained_bytes"]
FABRIC_FIELDS = ["tor_up_q_max_bytes"]
FAULT_FIELDS = ["retx_chunks", "msg_lost_chunks"]


def _fabric(mode: int, n_hosts: int, faults: bool):
    """0 = single switch; 1 = 2 racks; 2 = one host per rack."""
    if mode == 0:
        return None
    fl = FaultConfig(up_loss=0.01, down_loss=0.005, seed=3,
                     resend_slots=60, sender_timeout_slots=150) \
        if faults else None
    racks = 2 if mode == 1 else n_hosts
    return FabricConfig(racks=racks, oversub=2.0, up_cap=64, faults=fl)


def _literal(cfg_kw: dict, tbl_kw: dict) -> str:
    cfg_args = ", ".join(f"{k}={v!r}" for k, v in cfg_kw.items())
    tbl_args = ", ".join(f"{k}={v!r}" for k, v in tbl_kw.items())
    return (f"\n  repro:\n    SimConfig({cfg_args})\n"
            f"    make_messages({tbl_args})")


def _run_all(cfg_kw: dict, tbl_kw: dict):
    tbl = make_messages(**tbl_kw)
    results = {}
    for backend in BACKENDS:
        results[backend] = simulate(SimConfig(backend=backend, **cfg_kw),
                                    tbl)
    return results


def _assert_identical(cfg_kw: dict, tbl_kw: dict):
    results = _run_all(cfg_kw, tbl_kw)
    ref = results["reference"]
    fields = list(FIELDS)
    if cfg_kw.get("fabric") is not None:
        fields += FABRIC_FIELDS
        if cfg_kw["fabric"].faults is not None:
            fields += FAULT_FIELDS
    for backend in ("pallas", "pallas_fused"):
        r = results[backend]
        assert r.lost_chunks == ref.lost_chunks, (
            f"{backend} lost_chunks {r.lost_chunks} != "
            f"{ref.lost_chunks}" + _literal(
                {**cfg_kw, "backend": backend}, tbl_kw))
        for f in fields:
            a, b = getattr(ref, f), getattr(r, f)
            if not np.array_equal(a, b):
                i = np.flatnonzero(np.asarray(a) != np.asarray(b))[:5]
                raise AssertionError(
                    f"{backend} diverges from reference on {f} at "
                    f"indices {i.tolist()}" + _literal(
                        {**cfg_kw, "backend": backend}, tbl_kw))


# ----------------------------------------------------- fixed corner grid ---

CORNERS = [
    # (proto, n_hosts, fabric_mode, host, faults, ring_cap, overcommit)
    ("homa",    8, 0, None,            False, 256, None),
    ("homa",    8, 1, "kernel_stack",  True,  100, 2),
    ("basic",   6, 1, None,            False, 64,  None),
    ("phost",   8, 2, "kernel_bypass", False, 256, 1),
    ("pias",    4, 0, "kernel_stack",  False, 7,   None),
    ("pfabric", 8, 1, None,            True,  256, None),
    ("ndp",     6, 2, "kernel_bypass", False, 100, None),
    ("homa",   12, 2, None,            True,  64,  7),
]


@pytest.mark.parametrize("case", CORNERS,
                         ids=lambda c: f"{c[0]}-h{c[1]}-fab{c[2]}")
def test_differential_corner(case):
    """Hand-picked corners of the config space — run on every machine,
    hypothesis or not."""
    proto, n_hosts, fab_mode, host, faults, ring_cap, overcommit = case
    cfg_kw = dict(protocol=proto, n_hosts=n_hosts, max_slots=500,
                  ring_cap=ring_cap, overcommit=overcommit,
                  fabric=_fabric(fab_mode, n_hosts, faults), host=host)
    tbl_kw = dict(workload="W2", n_hosts=n_hosts, load=0.8,
                  n_messages=40, slot_bytes=256, seed=11)
    _assert_identical(cfg_kw, tbl_kw)


# -------------------------------------------------------- hypothesis fuzz --

@settings(max_examples=10, deadline=None)
@given(st.sampled_from(PROTOCOLS),
       st.sampled_from([4, 6, 8]),          # n_hosts (even: racks divide)
       st.integers(0, 2),                   # fabric mode
       st.sampled_from([None, "kernel_stack", "kernel_bypass"]),
       st.booleans(),                       # faults (fabric only)
       st.sampled_from([7, 100, 256]),      # ring_cap (ragged cols)
       st.sampled_from([None, 1, 2, 7]),    # overcommit (K sweep)
       st.sampled_from(["W1", "W2", "W4"]),
       st.integers(0, 999))                 # table seed
def test_differential_fuzz(proto, n_hosts, fab_mode, host, faults,
                           ring_cap, overcommit, workload, seed):
    """Random SimConfigs: protocol × fabric on/off × host preset ×
    faults × ragged H/cap shapes, all three backends bit-identical.
    Failures shrink and print a reproducible config literal."""
    cfg_kw = dict(protocol=proto, n_hosts=n_hosts, max_slots=400,
                  ring_cap=ring_cap, overcommit=overcommit,
                  fabric=_fabric(fab_mode, n_hosts, faults), host=host)
    tbl_kw = dict(workload=workload, n_hosts=n_hosts, load=0.8,
                  n_messages=30, slot_bytes=256, seed=seed)
    _assert_identical(cfg_kw, tbl_kw)
