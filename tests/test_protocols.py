"""Protocol-as-policy API tests: registry validation, structured results,
and the vmapped multi-seed sweep runner (bit-identity + single-trace)."""
import numpy as np
import pytest

from repro.core import (SimConfig, SimResult, SweepSpec, simulate,
                        run_sweep, get_protocol,
                        registered_protocols, make_messages)
from repro.core import sim as sim_mod
from repro.core.protocols import Protocol, register, _REGISTRY

ALL_PROTOS = ["homa", "basic", "phost", "pias", "pfabric", "ndp"]
SMALL = dict(n_hosts=4, max_slots=2500, ring_cap=512)


# ---------------------------------------------------------------- registry

def test_registry_has_all_six_protocols():
    assert registered_protocols() == sorted(ALL_PROTOS)


def test_unknown_protocol_raises_listing_registered():
    with pytest.raises(ValueError, match="unknown protocol 'tcpx'"):
        get_protocol("tcpx")
    with pytest.raises(ValueError, match="homa"):
        SimConfig(protocol="definitely-not-registered")


def test_register_custom_protocol_variant():
    import dataclasses

    @dataclasses.dataclass(frozen=True)
    class Homa2(type(get_protocol("homa"))):
        name: str = "homa-variant-test"
    register(Homa2())
    try:
        tbl = make_messages("W2", n_hosts=4, load=0.5, n_messages=80,
                            slot_bytes=256, seed=0)
        cfg = SimConfig(protocol="homa-variant-test", **SMALL)
        ref = simulate(dataclasses.replace(cfg, protocol="homa"), tbl)
        var = simulate(cfg, tbl)
        np.testing.assert_array_equal(ref.completion, var.completion)
    finally:
        del _REGISTRY["homa-variant-test"]


def test_step_fn_is_policy_agnostic():
    """The orchestration core must not branch on the protocol name."""
    import inspect
    src = inspect.getsource(sim_mod.step_fn)
    assert "cfg.protocol" not in src
    for name in ALL_PROTOS:
        assert f'"{name}"' not in src


# ---------------------------------------------------------- SimResult API

def test_simresult_fields_and_summary():
    tbl = make_messages("W2", n_hosts=4, load=0.6, n_messages=150,
                        slot_bytes=256, seed=1)
    res = simulate(SimConfig(protocol="homa", **SMALL), tbl)
    assert isinstance(res, SimResult)
    assert res.protocol == "homa"
    assert res.done.shape == (150,)
    assert 0.0 <= res.completion_rate <= 1.0
    s = res.summary()
    assert s["n_messages"] == 150
    assert set(s) >= {"p99_by_size", "p99_small", "busy_frac",
                      "prio_drained_bytes", "alloc", "completion_rate"}
    import json
    assert json.loads(res.to_json())["n_messages"] == 150


def test_legacy_shims_are_gone():
    """The deprecation release shipped; the shims must be fully removed,
    not just warning — the old names may not silently come back."""
    import repro.core
    import repro.core.sim
    assert not hasattr(repro.core, "run_sim")
    assert not hasattr(repro.core.sim, "run_sim")
    assert "run_sim" not in repro.core.__all__
    assert "run_sim" not in repro.core.sim.__all__
    assert not hasattr(SimResult, "to_legacy_dict")
    # the legacy run_sweep(cfg, tables, **kwargs) form errors loudly
    cfg = SimConfig(protocol="homa", **SMALL)
    tbl = make_messages("W3", n_hosts=4, load=0.7, n_messages=50,
                        slot_bytes=256, seed=2)
    with pytest.raises(TypeError, match="SweepSpec"):
        run_sweep(cfg, [tbl])
    with pytest.raises(TypeError):
        run_sweep(cfg, [tbl], shared_alloc=True)


# ----------------------------------------------------------- sweep runner

@pytest.mark.parametrize("proto", ALL_PROTOS)
def test_sweep_bit_identical_to_sequential(proto):
    cfg = SimConfig(protocol=proto, **SMALL)
    tables = [make_messages("W2", n_hosts=4, load=0.6, n_messages=100,
                            slot_bytes=256, seed=s) for s in range(3)]
    seq = [simulate(cfg, t) for t in tables]
    swe = run_sweep(cfg, SweepSpec(tables=tables))
    for a, b in zip(seq, swe):
        np.testing.assert_array_equal(a.completion, b.completion)
        np.testing.assert_array_equal(a.done, b.done)
        np.testing.assert_array_equal(a.prio_drained_bytes,
                                      b.prio_drained_bytes)
        np.testing.assert_array_equal(a.q_max_bytes, b.q_max_bytes)
        np.testing.assert_array_equal(a.q_mean_bytes, b.q_mean_bytes)
        ok = np.isfinite(a.slowdown)
        np.testing.assert_array_equal(ok, np.isfinite(b.slowdown))
        np.testing.assert_array_equal(a.slowdown[ok], b.slowdown[ok])
        assert a.lost_chunks == b.lost_chunks


def test_sweep_single_trace_with_shared_alloc():
    """8 seeds batch behind exactly one new compilation of the scan."""
    cfg = SimConfig(protocol="homa", n_hosts=4, max_slots=1200, ring_cap=128)
    tables = [make_messages("W1", n_hosts=4, load=0.8, n_messages=100,
                            slot_bytes=256, seed=s) for s in range(8)]
    before = sim_mod._run_batch._cache_size()
    res = run_sweep(cfg, SweepSpec(tables=tables, shared_alloc=True))
    assert sim_mod._run_batch._cache_size() == before + 1
    assert len(res) == 8
    assert all(r.n_complete > 0 for r in res)


def test_sweep_per_table_alloc_and_unsched_limit():
    """Ablation sweeps: one table, per-run alloc/unsched-limit overrides."""
    from repro.core.priorities import allocate_priorities
    from repro.core.workloads import sample_sizes
    tbl = make_messages("W1", n_hosts=4, load=0.7, n_messages=100,
                        slot_bytes=256, seed=0)
    sizes = sample_sizes("W1", 5000, np.random.default_rng(0))
    allocs = [allocate_priorities(sizes, unsched_limit=9728,
                                  force_unsched=nu) for nu in (1, 7)]
    cfg = SimConfig(protocol="homa", overcommit=1, **SMALL)
    swe = run_sweep(cfg, SweepSpec(tables=[tbl, tbl], alloc=allocs))
    seq = [simulate(cfg, tbl, alloc=a) for a in allocs]
    for a, b in zip(seq, swe):
        np.testing.assert_array_equal(a.completion, b.completion)
    # and per-table unscheduled limits (fig10 incast-control pattern)
    swe = run_sweep(cfg, SweepSpec(tables=[tbl, tbl],
                                   unsched_limit_bytes=[None, 512]))
    seq = [simulate(cfg, tbl), simulate(cfg, tbl, unsched_limit_bytes=512)]
    for a, b in zip(seq, swe):
        np.testing.assert_array_equal(a.completion, b.completion)


def test_sweep_mixed_lengths_group_not_reject():
    """Mixed-length tables are legal under SweepSpec: runs group by
    (length, n_sched) and come back in input order (the old runner
    rejected them outright)."""
    cfg = SimConfig(protocol="homa", **SMALL)
    t1 = make_messages("W1", n_hosts=4, load=0.5, n_messages=50,
                       slot_bytes=256, seed=0)
    t2 = make_messages("W1", n_hosts=4, load=0.5, n_messages=60,
                       slot_bytes=256, seed=0)
    swe = run_sweep(cfg, SweepSpec(tables=[t1, t2, t1]))
    seq = [simulate(cfg, t) for t in (t1, t2, t1)]
    for a, b in zip(seq, swe):
        np.testing.assert_array_equal(a.completion, b.completion)


def test_sweep_spec_validation():
    cfg = SimConfig(protocol="homa", **SMALL)
    tbl = make_messages("W1", n_hosts=4, load=0.5, n_messages=50,
                        slot_bytes=256, seed=0)
    with pytest.raises(ValueError, match="tables"):
        SweepSpec()
    with pytest.raises(TypeError, match="SweepSpec"):
        run_sweep(cfg, None)
    with pytest.raises(ValueError, match="chunk_slots"):
        SweepSpec(tables=[tbl], chunk_slots=0)
    with pytest.raises(ValueError, match="return_state"):
        SweepSpec(tables=[tbl], streaming=True, return_state=True)
    with pytest.raises(ValueError, match="alloc"):
        run_sweep(cfg, SweepSpec(tables=[tbl], alloc=[None, None]))


def test_sweep_faster_than_sequential_with_fresh_traces():
    """The acceptance demonstration at test scale: 8 seeds, legacy
    per-point configs (8 traces) vs one batched trace. The benchmark
    (benchmarks/sweep_speed.py) measures the <0.5x criterion; this gate
    is looser so CI timing noise can't flake it."""
    import time
    from repro.core.workloads import make_messages as mk
    tables = [mk("W1", n_hosts=8, load=0.8, n_messages=300,
                 slot_bytes=256, seed=100 + s) for s in range(8)]
    t0 = time.perf_counter()
    for t in tables:
        cfg = SimConfig(n_hosts=8, protocol="homa", ring_cap=256,
                        max_slots=int(t.arrival_slot.max()) + 600)
        simulate(cfg, t)
    seq_s = time.perf_counter() - t0
    horizon = max(int(t.arrival_slot.max()) for t in tables) + 600
    cfg = SimConfig(n_hosts=8, protocol="homa", ring_cap=256,
                    max_slots=horizon)
    t0 = time.perf_counter()
    res = run_sweep(cfg, SweepSpec(tables=tables, shared_alloc=True))
    sweep_s = time.perf_counter() - t0
    assert all(r.n_complete == 300 for r in res)
    assert sweep_s < 0.75 * seq_s, (sweep_s, seq_s)
