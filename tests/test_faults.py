"""Fault-injection & loss-recovery subsystem tests (DESIGN.md §7).

Covers the three tentpole pieces — loss/failure injection, Homa-style
receiver RESEND + sender-fallback recovery, and the pluggable spine
routing policies — plus the satellites: loss-aware conservation for
every protocol, retransmission liveness as a hypothesis property over
ragged shapes, and the workload-name validation fix.

Zero-fault bit-identity is pinned elsewhere (tests/test_fabric.py and
tests/test_backend.py against the goldens): ``FabricConfig.faults=None``
keeps those tests running the exact pre-fault program.
"""
import dataclasses
import functools
import json

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st_

from repro.core import (SimConfig, FabricConfig, FaultConfig, SweepSpec,
                        simulate, run_sweep, make_messages, scenarios)
from repro.core.faults import link_down_mask, host_down_mask

ALL_PROTOS = ["homa", "basic", "phost", "pias", "pfabric", "ndp"]


def _conserved(state) -> bool:
    """Loss-aware chunk conservation: every transmission (sent + rewind
    credits) is delivered, buffered in some tier, or accounted as lost
    (ring overflow at either tier, or fault-injected drop)."""
    return (int(state["sent"].sum()) + int(state["retx"].sum())
            == int(state["recv"].sum()) + int(state["r_valid"].sum())
            + int(state["u_valid"].sum()) + int(state["lost"])
            + int(state["u_lost"]) + int(state["f_lost"]))


@functools.lru_cache(maxsize=None)
def _loss_run(proto: str):
    """The acceptance-criterion run: W2 at 2:1 oversubscription with 1%
    uplink loss (shared across tests; jit-cached within the session)."""
    tbl = make_messages("W2", n_hosts=16, load=0.6, n_messages=250,
                        slot_bytes=256, seed=3)
    fab = FabricConfig(racks=4, oversub=2.0,
                       faults=FaultConfig(up_loss=0.01))
    cfg = SimConfig(n_hosts=16, protocol=proto, fabric=fab,
                    max_slots=20_000, ring_cap=1024)
    return simulate(cfg, tbl, return_state=True)


# ------------------------------------------ acceptance + conservation ----

@pytest.mark.parametrize("proto", ALL_PROTOS)
def test_all_protocols_complete_and_conserve_at_one_percent_loss(proto):
    """Acceptance: with 1% uplink loss on W2 at 2:1 oversub, every
    protocol recovers every message; and the conservation invariant
    holds under loss (satellite): delivered + buffered + dropped
    balances sent + retransmission credits exactly."""
    r = _loss_run(proto)
    assert r.n_complete == r.n_messages, (proto, r.n_complete)
    assert r.fault_lost_chunks > 0                  # loss actually happened
    assert int(np.sum(r.retx_chunks)) >= r.fault_lost_chunks
    assert _conserved(r.state), proto


def test_receiver_resend_recovers_faster_than_sender_fallback():
    """The point of §3.7: homa's receiver RESEND (~8 RTT quiet) beats
    basic's sender-only fallback timeout (~20 RTT) on mean recovery
    time for the same table and loss pattern."""
    rec = {}
    for proto in ("homa", "basic"):
        r = _loss_run(proto)
        hit = r.recovery_slots >= 0
        assert hit.any(), proto
        rec[proto] = float(np.mean(r.recovery_slots[hit]))
    assert rec["homa"] < rec["basic"], rec


def test_loss_on_both_legs_with_bursts_conserves():
    """Bernoulli up+down loss plus a Gilbert-Elliott burst chain at
    once: heavier, correlated loss still conserves and completes."""
    tbl = make_messages("W2", n_hosts=8, load=0.5, n_messages=150,
                        slot_bytes=256, seed=7)
    fab = FabricConfig(racks=2, oversub=2.0, faults=FaultConfig(
        up_loss=0.03, down_loss=0.02, ge_p_gb=0.01, ge_p_bg=0.1,
        ge_loss=0.5))
    cfg = SimConfig(n_hosts=8, protocol="homa", fabric=fab,
                    max_slots=20_000, ring_cap=512)
    r = simulate(cfg, tbl, return_state=True)
    assert r.n_complete == r.n_messages
    assert r.fault_lost_chunks > 0
    assert _conserved(r.state)


def test_ge_chain_disabled_by_default():
    """ge_p_gb=0 must never enter the bad state: a config with only the
    GE knobs left at defaults injects no loss at all."""
    tbl = make_messages("W1", n_hosts=8, load=0.5, n_messages=100,
                        slot_bytes=256, seed=1)
    fab = FabricConfig(racks=2, faults=FaultConfig())
    cfg = SimConfig(n_hosts=8, protocol="homa", fabric=fab,
                    max_slots=6000, ring_cap=512)
    r = simulate(cfg, tbl)
    assert r.fault_lost_chunks == 0
    assert not FaultConfig().any_loss


# ------------------------------------------------------ failure windows ----

def test_down_masks_follow_schedules():
    fab = FabricConfig(racks=4, oversub=2.0, faults=FaultConfig(
        link_fail=((1, 100, 200),), tor_fail=((2, 150, 250),)))
    cfg = SimConfig(n_hosts=16, protocol="homa", fabric=fab)
    U = fab.n_uplinks_total(16)             # 4 racks x 2 uplinks
    assert U == 8
    assert not np.asarray(link_down_mask(cfg, 99)).any()
    m = np.asarray(link_down_mask(cfg, 150))
    # uplink 1 (window) + rack 2's uplinks 4,5 (TOR window) are down
    assert m.tolist() == [False, True, False, False, True, True,
                          False, False]
    assert not np.asarray(link_down_mask(cfg, 200))[1]
    h = np.asarray(host_down_mask(cfg, 160))
    assert h.tolist() == [False] * 8 + [True] * 4 + [False] * 4
    assert not np.asarray(host_down_mask(cfg, 250)).any()


def test_tor_failure_window_recovers():
    """A whole TOR dark for 1500 slots: traffic to/from the rack stalls,
    then recovery timeouts carry every message across the window."""
    tbl = make_messages("W2", n_hosts=16, load=0.5, n_messages=150,
                        slot_bytes=256, seed=5)
    fab = scenarios.tor_failure(
        FabricConfig(racks=4, oversub=2.0), rack=1, start=200, end=1700)
    cfg = SimConfig(n_hosts=16, protocol="homa", fabric=fab,
                    max_slots=25_000, ring_cap=1024)
    r = simulate(cfg, tbl, return_state=True)
    assert r.n_complete == r.n_messages
    assert r.fault_lost_chunks > 0
    assert _conserved(r.state)


# ------------------------------------------------------ routing policies ---

def _failed_uplink_run(routing: str):
    tbl = make_messages("W2", n_hosts=16, load=0.6, n_messages=200,
                        slot_bytes=256, seed=5)
    fab = scenarios.uplink_failure(
        FabricConfig(racks=4, oversub=2.0, routing=routing),
        uplink=0, start=500, end=4000)
    cfg = SimConfig(n_hosts=16, protocol="homa", fabric=fab,
                    max_slots=20_000, ring_cap=1024)
    return simulate(cfg, tbl)


def test_routing_policies_react_to_failed_uplink():
    """The RepFlow point: static ECMP keeps hashing flows into the dead
    spine for the whole window (they stall until it lifts); flowlet
    escapes at the next epoch boundary; adaptive never touches the dead
    uplink. Drop *counts* for the static policies depend on how often
    the recovery timers retry into the black hole, so the robust
    ordering is on the tail latency, not the drop totals."""
    res = {r: _failed_uplink_run(r) for r in ("ecmp", "flowlet",
                                              "adaptive")}
    for routing, r in res.items():
        assert r.n_complete == r.n_messages, routing
        assert r.fabric["routing"] == routing
    assert res["adaptive"].fault_lost_chunks == 0
    assert res["ecmp"].fault_lost_chunks > 0
    assert res["flowlet"].fault_lost_chunks > 0
    # the tail orders by how fast each policy escapes the dead spine
    p99 = {k: r.summary()["p99_small"] for k, r in res.items()}
    assert p99["adaptive"] < p99["flowlet"] < p99["ecmp"], p99


def test_adaptive_routing_balances_load_without_faults():
    """Routing policies work standalone (faults=None): adaptive spreads
    a shuffle across uplinks at least as evenly as static ECMP."""
    tbl = scenarios.shuffle(n_hosts=16, bytes_per_pair=8000,
                            spread_slots=1500, seed=2)
    busy = {}
    for routing in ("ecmp", "adaptive"):
        fab = FabricConfig(racks=4, oversub=2.0, routing=routing)
        cfg = SimConfig(n_hosts=16, protocol="homa", max_slots=12_000,
                        ring_cap=1024, fabric=fab)
        r = simulate(cfg, tbl)
        assert r.n_complete == r.n_messages, routing
        busy[routing] = r.tor_up_busy_frac
    # adaptive's per-uplink utilization spread is no worse than ECMP's
    assert busy["adaptive"].std() <= busy["ecmp"].std() + 1e-9, busy


# --------------------------------------------------------- composition ----

def test_faults_compose_with_run_sweep():
    """Loss draws are counter-based (no PRNG state, no batch-index
    dependence): vmapped sweeps stay bit-identical to sequential runs."""
    fab = FabricConfig(racks=4, oversub=2.0, routing="flowlet",
                       faults=FaultConfig(up_loss=0.02, seed=9))
    cfg = SimConfig(n_hosts=16, protocol="homa", fabric=fab,
                    max_slots=6000, ring_cap=512)
    tables = [make_messages("W2", n_hosts=16, load=0.6, n_messages=120,
                            slot_bytes=256, seed=s) for s in range(3)]
    seq = [simulate(cfg, t, return_state=True) for t in tables]
    swe = run_sweep(cfg, SweepSpec(tables=tables, return_state=True))
    for a, b in zip(seq, swe):
        np.testing.assert_array_equal(a.completion, b.completion)
        np.testing.assert_array_equal(a.retx_chunks, b.retx_chunks)
        np.testing.assert_array_equal(a.msg_lost_chunks, b.msg_lost_chunks)
        assert a.fault_lost_chunks == b.fault_lost_chunks


def test_fault_runs_reproducible_and_seed_sensitive():
    tbl = make_messages("W2", n_hosts=8, load=0.5, n_messages=100,
                        slot_bytes=256, seed=0)
    def run(seed):
        fab = FabricConfig(racks=2, faults=FaultConfig(up_loss=0.05,
                                                       seed=seed))
        cfg = SimConfig(n_hosts=8, protocol="homa", fabric=fab,
                        max_slots=8000, ring_cap=512)
        return simulate(cfg, tbl)
    a, b, c = run(0), run(0), run(1)
    np.testing.assert_array_equal(a.completion, b.completion)
    assert a.fault_lost_chunks == b.fault_lost_chunks
    assert (np.asarray(a.completion) != np.asarray(c.completion)).any() \
        or a.fault_lost_chunks != c.fault_lost_chunks


def test_faults_bit_identical_across_backends():
    """The fault layer rides the backend contract (DESIGN.md §6): the
    pallas leg reproduces the reference leg bit-for-bit under loss."""
    tbl = make_messages("W2", n_hosts=8, load=0.6, n_messages=50,
                        slot_bytes=256, seed=2)
    fab = FabricConfig(racks=2, oversub=2.0,
                       faults=FaultConfig(up_loss=0.05))
    out = {}
    for backend in ("reference", "pallas"):
        cfg = SimConfig(n_hosts=8, protocol="homa", fabric=fab,
                        max_slots=1500, ring_cap=256, backend=backend)
        out[backend] = simulate(cfg, tbl)
    np.testing.assert_array_equal(out["reference"].completion,
                                  out["pallas"].completion)
    np.testing.assert_array_equal(out["reference"].retx_chunks,
                                  out["pallas"].retx_chunks)
    assert out["reference"].fault_lost_chunks \
        == out["pallas"].fault_lost_chunks


# ------------------------------------------------------- stats plumbing ----

def test_recovery_stats_in_summary_and_json():
    r = _loss_run("homa")
    s = json.loads(r.to_json())
    fl = s["faults"]
    assert fl["up_loss"] == 0.01
    assert fl["fault_lost_chunks"] == r.fault_lost_chunks > 0
    assert fl["retx_chunks"] == int(np.sum(r.retx_chunks))
    assert fl["msgs_lossy"] == int(np.sum(r.msg_lost_chunks > 0)) > 0
    assert fl["recovery_mean_slots"] > 0
    assert fl["recovery_p99_slots"] >= fl["recovery_mean_slots"]
    assert s["fabric"]["routing"] == "ecmp"
    # recovery_slots is -1 exactly for the messages never hit by loss
    hit = r.msg_lost_chunks > 0
    assert (r.recovery_slots[~hit] == -1).all()
    assert (r.recovery_slots[hit & (r.completion >= 0)] >= 0).all()
    # fault-free runs keep the schema (faults: null)
    clean = simulate(SimConfig(n_hosts=4, max_slots=1500, ring_cap=256),
                     make_messages("W1", n_hosts=4, load=0.5,
                                   n_messages=50, slot_bytes=256, seed=0))
    assert json.loads(clean.to_json())["faults"] is None
    assert clean.retx_chunks is None and clean.fault_lost_chunks == 0


# ------------------------------------------------- config validation -------

def test_fault_config_validation_errors():
    fab = dict(racks=4, oversub=2.0)
    with pytest.raises(ValueError, match="up_loss"):
        SimConfig(n_hosts=16, fabric=FabricConfig(
            **fab, faults=FaultConfig(up_loss=1.5)))
    with pytest.raises(ValueError, match="ge_p_bg"):
        SimConfig(n_hosts=16, fabric=FabricConfig(
            **fab, faults=FaultConfig(ge_p_gb=0.1, ge_p_bg=0.0)))
    with pytest.raises(ValueError, match="link_fail"):
        SimConfig(n_hosts=16, fabric=FabricConfig(
            **fab, faults=FaultConfig(link_fail=((99, 0, 100),))))
    with pytest.raises(ValueError, match="tor_fail"):
        SimConfig(n_hosts=16, fabric=FabricConfig(
            **fab, faults=FaultConfig(tor_fail=((0, 100, 100),))))
    with pytest.raises(ValueError, match="timeouts"):
        SimConfig(n_hosts=16, fabric=FabricConfig(
            **fab, faults=FaultConfig(resend_slots=0)))
    with pytest.raises(ValueError, match="routing"):
        SimConfig(n_hosts=16, fabric=FabricConfig(**fab,
                                                  routing="spray"))
    with pytest.raises(ValueError, match="flowlet_slots"):
        SimConfig(n_hosts=16, fabric=FabricConfig(**fab,
                                                  flowlet_slots=0))
    # JSON round-trip: dict faults + list windows normalize and hash
    fab2 = FabricConfig(racks=4, faults=dict(up_loss=0.01,
                                             link_fail=[[0, 10, 20]]))
    assert isinstance(fab2.faults, FaultConfig)
    assert fab2.faults.link_fail == ((0, 10, 20),)
    hash(fab2)


def test_scenario_fault_helpers():
    fab = FabricConfig(racks=4, oversub=2.0)
    lossy = scenarios.lossy_fabric(fab, up_loss=0.02, ge_p_gb=0.01)
    assert lossy.faults.up_loss == 0.02 and lossy.faults.ge_on
    stacked = scenarios.tor_failure(
        scenarios.uplink_failure(lossy, uplink=3, start=0, end=50),
        rack=2, start=10, end=90)
    assert stacked.faults.up_loss == 0.02          # composition preserves
    assert stacked.faults.link_fail == ((3, 0, 50),)
    assert stacked.faults.tor_fail == ((2, 10, 90),)
    with pytest.raises(ValueError, match="enabled fabric"):
        scenarios.lossy_fabric(FabricConfig(None), up_loss=0.1)


def test_unknown_workload_raises_valueerror_listing_bins():
    """Satellite: sample_sizes/make_messages raised a bare KeyError on
    unknown workload names; now a ValueError listing WORKLOAD_BINS."""
    from repro.core.workloads import sample_sizes
    with pytest.raises(ValueError, match=r"unknown workload 'W9'.*W1"):
        sample_sizes("W9", 10, np.random.default_rng(0))
    with pytest.raises(ValueError, match="available workloads"):
        make_messages("web-search", n_hosts=4, load=0.5, n_messages=10,
                      slot_bytes=256)


# ------------------------------------------- property: liveness (§3.7) ----

@settings(max_examples=8, deadline=None)
@given(proto=st_.sampled_from(ALL_PROTOS),
       n_hosts=st_.sampled_from([4, 8]),
       racks=st_.sampled_from([1, 2]),
       n_messages=st_.integers(min_value=10, max_value=50),
       loss=st_.sampled_from([0.0, 0.1, 0.3, 0.5, 0.7]),
       seed=st_.integers(min_value=0, max_value=5))
def test_retransmission_liveness(proto, n_hosts, racks, n_messages, loss,
                                 seed):
    """For any loss rate < 1 and any protocol, every message eventually
    completes: the recovery timers guarantee retransmission liveness
    over ragged host/message shapes (hypothesis satellite)."""
    tbl = make_messages("W1", n_hosts=n_hosts, load=0.5,
                        n_messages=n_messages, slot_bytes=256, seed=seed,
                        max_bytes=2000)
    fab = FabricConfig(racks=racks, oversub=2.0, faults=FaultConfig(
        up_loss=loss, down_loss=loss / 2,
        resend_slots=40, sender_timeout_slots=60))
    cfg = SimConfig(n_hosts=n_hosts, protocol=proto, fabric=fab,
                    max_slots=6000, ring_cap=512)
    r = simulate(cfg, tbl, return_state=True)
    assert r.n_complete == r.n_messages, \
        (proto, n_hosts, racks, loss, seed, r.n_complete)
    assert _conserved(r.state)
