"""Per-kernel validation: shape/dtype sweeps asserting allclose against the
pure-jnp oracles (interpret mode on CPU), plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.attention.ops import attention
from repro.kernels.attention.ref import attention_ref
from repro.kernels.ssd.ops import ssd
from repro.kernels.ssd.ref import ssd_ref
from repro.kernels.arbiter import dispatch
from repro.kernels.arbiter import ops as arb_ops
from repro.kernels.arbiter.ref import priority_arbiter_ref, srpt_topk_ref


# ------------------------------------------------------------ attention ----

ATTN_CASES = [
    # (B, Sq, Skv, H, KV, d, causal, window, dtype)
    (1, 64, 64, 4, 4, 32, True, None, jnp.float32),
    (2, 96, 96, 4, 2, 16, True, None, jnp.float32),
    (1, 128, 128, 8, 1, 64, True, 32, jnp.float32),
    (2, 64, 64, 2, 2, 32, False, None, jnp.float32),
    (1, 80, 80, 4, 4, 32, True, None, jnp.bfloat16),
    (1, 33, 33, 2, 2, 8, True, None, jnp.float32),   # ragged block
]


@pytest.mark.parametrize("case", ATTN_CASES)
def test_attention_matches_ref(case):
    B, Sq, Skv, H, KV, d, causal, window, dtype = case
    ks = jax.random.split(jax.random.key(42), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, d), dtype)
    k = jax.random.normal(ks[1], (B, Skv, KV, d), dtype)
    v = jax.random.normal(ks[2], (B, Skv, KV, d), dtype)
    out = attention(q, k, v, causal=causal, window=window,
                    block_q=32, block_kv=32, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 3), st.integers(1, 4), st.integers(1, 3),
       st.sampled_from([8, 16, 32]), st.booleans())
def test_attention_property(b, kv, g, d, causal):
    """Rows of the attention output are convex combinations of V rows:
    output must lie within [min(v), max(v)] per dim."""
    h = kv * g
    s = 40
    ks = jax.random.split(jax.random.key(b * 100 + kv * 10 + g), 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kv, d))
    v = jax.random.normal(ks[2], (b, s, kv, d))
    out = np.asarray(attention(q, k, v, causal=causal, block_q=16,
                               block_kv=16, interpret=True), np.float32)
    vmax = float(np.asarray(v, np.float32).max())
    vmin = float(np.asarray(v, np.float32).min())
    assert out.max() <= vmax + 1e-3 and out.min() >= vmin - 1e-3
    assert np.isfinite(out).all()


# ------------------------------------------------------------------ SSD ----

SSD_CASES = [
    # (B, S, H, P, N, chunk)
    (1, 32, 2, 8, 8, 8),
    (2, 64, 3, 8, 16, 16),
    (1, 48, 1, 16, 16, 16),   # pad path
    (2, 128, 4, 16, 32, 32),
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_matches_ref(case):
    B, S, H, P, N, chunk = case
    ks = jax.random.split(jax.random.key(7), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.5
    y, fs = ssd(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    yr, fr = ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(np.asarray(fs), np.asarray(fr),
                               atol=5e-4, rtol=5e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_ssd_decay_property(seed):
    """With A << 0 (fast decay) the state forgets: doubling early inputs must
    not change late outputs materially."""
    ks = jax.random.split(jax.random.key(seed), 5)
    B, S, H, P, N = 1, 32, 1, 4, 4
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jnp.ones((B, S, H)) * 2.0
    A = jnp.full((H,), -8.0)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    y1, _ = ssd(x, dt, A, Bm, Cm, chunk=8, interpret=True)
    x2 = x.at[:, :8].mul(2.0)
    y2, _ = ssd(x2, dt, A, Bm, Cm, chunk=8, interpret=True)
    np.testing.assert_allclose(np.asarray(y1[:, -8:]), np.asarray(y2[:, -8:]),
                               atol=1e-3)


# -------------------------------------------------------------- arbiter ----

# (13, 100) and (8, 1000) exercise the padded ragged path: the old
# heuristic (`bc = 256 if cap % 256 == 0 else cap`) degenerated to one
# un-tiled block for any non-multiple capacity; dispatch now pads
# columns up to the block multiple instead (satellite fix).
@pytest.mark.parametrize("H,cap", [(8, 256), (16, 512), (4, 64), (13, 100),
                                   (8, 1000), (1, 1)])
def test_arbiter_matches_ref(H, cap):
    rng = np.random.default_rng(H * cap)
    prio = jnp.asarray(rng.integers(0, 8, (H, cap)), jnp.int32)
    seq = jnp.asarray(rng.integers(0, 10_000, (H, cap)), jnp.int32)
    elig = jnp.asarray(rng.random((H, cap)) < 0.3)
    bp, bi = arb_ops.arbitrate(prio, seq, elig, interpret=True)
    rp, ri = priority_arbiter_ref(prio, seq, elig)
    np.testing.assert_array_equal(np.asarray(bp), np.asarray(rp))
    # exact index equality: both backends break (prio, seq) ties toward
    # the lowest slot, and the simulator's ring state depends on it
    np.testing.assert_array_equal(np.asarray(bi), np.asarray(ri))


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 12), st.integers(1, 60), st.integers(0, 2 ** 16),
       st.sampled_from([0.0, 0.3, 1.0]))
def test_arbitrate_matches_ring_drain_select(H, cap, seed, p_elig):
    """Property (satellite): ``dispatch.arbitrate`` equals the simulator's
    ``ring_drain_select`` oracle — winner index, priority, eligibility —
    over ragged H/cap shapes, dense ties, and all-ineligible rows, for
    BOTH backends."""
    from repro.core.fabric import ring_drain_select
    rng = np.random.default_rng(seed)
    prio = jnp.asarray(rng.integers(0, 4, (H, cap)), jnp.int32)
    seq = jnp.asarray(rng.integers(0, 8, (H, cap)), jnp.int32)  # dense ties
    elig = jnp.asarray(rng.random((H, cap)) < p_elig)
    elig = elig.at[0].set(False)              # force an all-ineligible row
    slot_idx, any_e, pmin = ring_drain_select(prio, seq, elig)
    for backend in ("reference", "pallas"):
        bp, bi = dispatch.arbitrate(prio, seq, elig, backend=backend,
                                    interpret=True)
        np.testing.assert_array_equal(np.asarray(bp), np.asarray(pmin))
        np.testing.assert_array_equal(np.asarray(bp < 2 ** 30),
                                      np.asarray(any_e))
        np.testing.assert_array_equal(np.asarray(bi), np.asarray(slot_idx))


@pytest.mark.parametrize("H,M,K", [(8, 512, 7), (16, 1024, 4), (4, 128, 1),
                                   (8, 512, 8), (13, 60, 5)])
def test_topk_matches_ref(H, M, K):
    rng = np.random.default_rng(H + M + K)
    keys = jnp.asarray(rng.integers(0, 1 << 28, (H, M)), jnp.int32)
    keys = jnp.where(jnp.asarray(rng.random((H, M)) < 0.5), keys, 0)
    vals, idx = arb_ops.topk(keys, K, interpret=True)
    rv, ri = srpt_topk_ref(keys, K)
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ri))


def test_topk_short_rows_use_ineligible_sentinel():
    """Regression (satellite): with M < K the columns used to be
    zero-filled, which collides with legitimate zero keys — with an
    index output that could surface a padding column as a winner. Pads
    must use the NEG sentinel: absent slots report (0, -1) and no index
    ever points outside the real columns."""
    keys = jnp.asarray([[0, 5, 0]], jnp.int32)          # legit zero keys
    vals, idx = arb_ops.topk(keys, 5, interpret=True)
    np.testing.assert_array_equal(np.asarray(vals), [[5, 0, 0, 0, 0]])
    np.testing.assert_array_equal(np.asarray(idx), [[1, -1, -1, -1, -1]])
    rv, ri = srpt_topk_ref(keys, 5)
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ri))
    # all-zero rows: nothing is eligible, nothing points at padding
    z_vals, z_idx = arb_ops.topk(jnp.zeros((2, 3), jnp.int32), 4,
                                 interpret=True)
    assert (np.asarray(z_vals) == 0).all() and (np.asarray(z_idx) == -1).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 12), st.integers(1, 60), st.integers(1, 8),
       st.integers(0, 2 ** 16))
def test_topk_property(H, M, K, seed):
    rng = np.random.default_rng(seed)
    keys = jnp.asarray(rng.integers(0, 1 << 20, (H, M)), jnp.int32)
    vals, idx = arb_ops.topk(keys, K, interpret=True)
    rv, ri = srpt_topk_ref(keys, K)
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ri))
