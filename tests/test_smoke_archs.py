"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and finiteness; plus prefill->decode consistency
where the families make it meaningful."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES
from repro.configs.reduced import reduced_config
from repro.models import model as M
from repro.models.params import init_params

B, S = 2, 32


def make_batch(cfg, key):
    k1, k2, k3 = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(k2, (B, S), 0, cfg.vocab_size)}
    if cfg.is_encoder_decoder:
        batch["enc_embeds"] = jax.random.normal(
            k3, (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    if cfg.num_image_tokens:
        batch["img_embeds"] = jax.random.normal(
            k3, (B, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.fixture(scope="module")
def setups():
    return {}


def _setup(name):
    cfg = reduced_config(name)
    params = init_params(M.model_defs(cfg), jax.random.key(0))
    batch = make_batch(cfg, jax.random.key(1))
    return cfg, params, batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_train(name):
    cfg, params, batch = _setup(name)
    logits, aux = M.forward_train(
        cfg, params, batch["tokens"],
        enc_embeds=batch.get("enc_embeds"), img_embeds=batch.get("img_embeds"))
    assert logits.shape == (B, S, cfg.padded_vocab())
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_decreases_loss(name):
    """One SGD step on a repeated batch must reduce loss (end-to-end grad)."""
    cfg, params, batch = _setup(name)

    def loss(p):
        return M.loss_fn(cfg, p, batch)[0]

    l0, g = jax.value_and_grad(loss)(params)
    assert bool(jnp.isfinite(l0))
    gnorm = jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                         for x in jax.tree.leaves(g)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0
    lr = 0.3 / max(float(gnorm), 1.0)
    p2 = jax.tree.map(lambda p, gg: (p.astype(jnp.float32)
                                     - lr * gg.astype(jnp.float32)).astype(p.dtype),
                      params, g)
    l1 = loss(p2)
    assert float(l1) < float(l0), (name, float(l0), float(l1))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_decode_consistency(name):
    """Prefill S-1 tokens then decode token S-1; logits must match a full
    forward at position S-1 (same math, different code paths)."""
    cfg, params, batch = _setup(name)
    tokens = batch["tokens"]
    kw = dict(enc_embeds=batch.get("enc_embeds"),
              img_embeds=batch.get("img_embeds"))

    full_logits, _ = M.forward_train(cfg, params, tokens, **kw)
    ref = full_logits[:, -1]

    logits_p, caches = M.forward_prefill(cfg, params, tokens[:, :-1], **kw)
    logits_d, deltas = M.forward_decode(cfg, params, tokens[:, -1:], S - 1,
                                        caches)
    np.testing.assert_allclose(np.asarray(logits_d, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=0.15, atol=0.3)
    # deltas structurally sound
    for leaf in jax.tree.leaves(deltas):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all())
