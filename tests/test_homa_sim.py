"""Homa simulator invariants + protocol behaviour tests (the paper's §3
mechanisms), with hypothesis property tests on the priority-allocation
policy."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.sim import SimConfig, simulate
from repro.core.workloads import MessageTable, make_messages, sample_sizes
from repro.core.priorities import (allocate_priorities, equal_bytes_cutoffs,
                                   pias_thresholds)


def table_from(src, dst, size, arrival, slot_bytes=256):
    return MessageTable(np.asarray(src, np.int32), np.asarray(dst, np.int32),
                        np.asarray(size, np.int64),
                        np.asarray(arrival, np.int32), "custom", 0.0,
                        slot_bytes)


SMALL = dict(n_hosts=4, max_slots=4000, ring_cap=512)


# ------------------------------------------------------------ invariants ---

@pytest.mark.parametrize("load", [0.5, 0.9])
@pytest.mark.parametrize("proto", ["homa", "basic", "phost", "pias",
                                   "pfabric", "ndp"])
def test_conservation_and_completion(proto, load):
    """Chunk conservation + causality for every registered protocol, at a
    moderate and a near-saturation load (scatter/drop bugs the percentile
    tests can't see)."""
    tbl = make_messages("W2", n_hosts=4, load=load, n_messages=300,
                        slot_bytes=256, seed=5)
    cfg = SimConfig(protocol=proto, **SMALL)
    res = simulate(cfg, tbl, return_state=True)
    st, S = res.state, res.static
    # no chunk created or destroyed: recv + in-buffer + lost == sent
    in_buf = int(st["r_valid"].sum())
    assert int(st["recv"].sum()) + in_buf + res.lost_chunks \
        == int(st["sent"].sum())
    # receivers never got more than the message size
    assert (st["recv"] <= S["size"]).all()
    # completed messages are fully received
    done = st["completion"] >= 0
    np.testing.assert_array_equal(st["recv"][done], S["size"][done])
    # senders never send beyond size or grant
    assert (st["sent"] <= S["size"]).all()
    # causality: nothing completes before it arrives
    assert (st["completion"][done] >= S["arrival"][done]).all()


def test_grant_invariant_rtt_bound():
    """Granted-but-not-received never exceeds RTTbytes (paper §3.3)."""
    tbl = make_messages("W4", n_hosts=4, load=0.7, n_messages=200,
                        slot_bytes=256, seed=6)
    cfg = SimConfig(protocol="homa", **SMALL)
    res = simulate(cfg, tbl, return_state=True)
    st = res.state
    outstanding = st["grant_r"] - st["recv"]
    assert (outstanding <= cfg.rtt_slots).all()


def test_unloaded_slowdown_near_one():
    rng = np.random.default_rng(0)
    n = 60
    tbl = table_from(rng.integers(0, 4, n),
                     (rng.integers(0, 4, n) + 1) % 4,
                     rng.integers(100, 50_000, n),
                     np.arange(n) * 400)           # sparse arrivals
    # fix dst != src
    tbl.dst[tbl.dst == tbl.src] = (tbl.src[tbl.dst == tbl.src] + 1) % 4
    cfg = SimConfig(protocol="homa", n_hosts=4, max_slots=30_000)
    res = simulate(cfg, tbl)
    sl = res.slowdown[res.done]
    assert res.n_complete >= n - 2
    assert np.nanmedian(sl) <= 1.05
    assert np.nanpercentile(sl, 99) <= 1.3


def test_srpt_shorter_message_wins():
    """Two messages to one receiver; the short one preempts and finishes
    first even though the long one started earlier."""
    tbl = table_from([1, 2], [0, 0], [200_000, 2_000], [0, 120])
    cfg = SimConfig(protocol="homa", n_hosts=4, max_slots=6000)
    res = simulate(cfg, tbl)
    assert res.done.all()
    assert res.completion[1] < res.completion[0]


def test_overcommitment_fills_idle_downlink():
    """Fig. 6 scenario: S1's SRPT prefers its message to R2 (shorter), so
    R0's single grant to S1 goes unanswered; with overcommitment R0 also
    grants S2's longer message and its downlink stays busy."""
    # S1 -> R0 (60k): blind goes out first, then S1's SRPT switches to its
    # shorter m1 (40k -> R2) when it arrives, stalling m0. R0 (K=1) keeps
    # granting stalled m0; S2's m2 (80k) can only use the idle downlink if
    # R0 overcommits.
    tbl = table_from([1, 1, 2], [0, 2, 0], [60_000, 40_000, 80_000],
                     [0, 50, 0])
    m2_done = {}
    for k in (1, 4):
        cfg = SimConfig(protocol="homa", overcommit=k, n_hosts=4,
                        max_slots=3000)
        res = simulate(cfg, tbl)
        assert res.done.all()
        m2_done[k] = int(res.completion[2])
    # with overcommitment m2 streams concurrently instead of waiting for
    # m0's run-to-completion -> finishes much earlier
    assert m2_done[4] * 1.5 < m2_done[1], m2_done


def test_homa_beats_basic_tail_latency():
    tbl = make_messages("W3", n_hosts=4, load=0.8, n_messages=600,
                        slot_bytes=256, seed=7)
    p99 = {}
    for proto in ("homa", "basic"):
        cfg = SimConfig(protocol=proto, n_hosts=4, max_slots=25_000,
                        ring_cap=1024)
        res = simulate(cfg, tbl)
        ok = res.done & (res.size_bytes < 3000)
        p99[proto] = np.percentile(res.slowdown[ok], 99)
    assert p99["homa"] * 2 < p99["basic"], p99


def test_incast_unsched_limit_bounds_buffers():
    """Paper §3.6: marking messages with a small unscheduled limit bounds
    TOR buffer use under a 30-way incast."""
    n = 30
    tbl = table_from(np.arange(n) % 3 + 1, np.zeros(n), np.full(n, 9728),
                     np.zeros(n))
    cfg = SimConfig(protocol="homa", n_hosts=4, max_slots=4000)
    free = simulate(cfg, tbl)
    lim = simulate(cfg, tbl, unsched_limit_bytes=512)
    assert lim.q_max_bytes[0] < free.q_max_bytes[0]
    assert lim.done.all()


# ------------------------------------------------- priority allocation -----

def test_allocation_matches_paper_shape():
    """W1-like tiny-message workloads get many unscheduled levels; W5-like
    heavy-tailed ones get few (paper Fig. 21 / §5.2)."""
    w1 = allocate_priorities(sample_sizes("W1", 20_000,
                                          np.random.default_rng(0)),
                             unsched_limit=9728)
    w5 = allocate_priorities(sample_sizes("W5", 20_000,
                                          np.random.default_rng(0)),
                             unsched_limit=9728)
    assert w1.n_unsched >= 6
    assert w5.n_unsched <= 2
    assert w1.unsched_bytes_frac > 0.9
    assert w5.unsched_bytes_frac < 0.2


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 8), st.integers(0, 10_000))
def test_cutoffs_balance_bytes(n_levels, seed):
    rng = np.random.default_rng(seed)
    sizes = sample_sizes("W3", 5000, rng)
    w = np.minimum(sizes, 9728).astype(np.float64)
    cuts = equal_bytes_cutoffs(sizes, w, n_levels)
    assert len(cuts) == n_levels - 1
    assert all(cuts[i] <= cuts[i + 1] for i in range(len(cuts) - 1))
    # each bucket's weight is within 2x of the ideal equal share
    edges = [0] + list(cuts) + [int(sizes.max()) + 1]
    shares = []
    for lo, hi in zip(edges[:-1], edges[1:]):
        m = (sizes > lo) & (sizes <= hi) if lo else (sizes <= hi)
        shares.append(w[m].sum())
    total = sum(shares)
    assert total > 0
    for s in shares[:-1]:
        assert s <= 2.2 * total / n_levels


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_unsched_prio_monotone(seed):
    rng = np.random.default_rng(seed)
    sizes = sample_sizes("W2", 3000, rng)
    alloc = allocate_priorities(sizes, unsched_limit=9728)
    s_sorted = np.sort(sizes)
    prios = alloc.unsched_prio(s_sorted)
    assert (np.diff(prios) <= 0).all()          # bigger msg -> lower prio
    assert prios.max() == alloc.n_prios - 1


def test_pias_thresholds_monotone():
    sizes = sample_sizes("W4", 4000, np.random.default_rng(1))
    th = pias_thresholds(sizes, 8)
    assert all(th[i] <= th[i + 1] for i in range(len(th) - 1))
