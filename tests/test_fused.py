"""Fused mega-kernel tests (DESIGN.md §11).

Three layers, mirroring the satellite checklist:

  1. the shared pad-and-tile policy in ``dispatch.pad_tiles`` /
     ``pad_min_cols`` (rows→8, cols→128, M<K NEG-sentinel fill) — the
     one helper behind ``arbitrate``, ``topk`` AND ``fused_slot``;
  2. fused-kernel edge cases — all-ineligible slots, single-host racks,
     cap not a block multiple, K > eligible messages, the empty-grant-set
     slot — each stage of ``dispatch.fused_slot`` asserted equal to the
     STAGED path (``pallas_arbitrate``/``pallas_topk``/the pure-jnp
     oracles), not just end-to-end;
  3. the batched slots-per-invocation variant: ``fused_slot_batch`` ==
     ``vmap(fused_slot)`` == stacked single calls, including the
     ``custom_vmap`` rewrite the sweep path relies on.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.arbiter import dispatch, fused
from repro.kernels.arbiter.kernel import BIG, NEG
from repro.kernels.arbiter.ref import priority_arbiter_ref, srpt_topk_ref


def _drain_problem(rng, H, cap, frac=0.3):
    prio = jnp.asarray(rng.integers(0, 8, (H, cap)), jnp.int32)
    seq = jnp.asarray(rng.integers(0, 4096, (H, cap)), jnp.int32)
    elig = jnp.asarray(rng.random((H, cap)) < frac)
    return prio, seq, elig


def _keys(rng, H, M, frac=0.5):
    k = jnp.asarray(rng.integers(1, 1 << 20, (H, M)), jnp.int32)
    return jnp.where(jnp.asarray(rng.random((H, M)) < frac), k, 0)


def _assert_stages(out, down=None, up=None, topk=None):
    """Every present fused stage == the staged reference oracle."""
    if down is not None:
        bp, bi = priority_arbiter_ref(*down)
        np.testing.assert_array_equal(out["down"][0], bp)
        np.testing.assert_array_equal(out["down"][1], bi)
    if up is not None:
        bp, bi = priority_arbiter_ref(*up)
        np.testing.assert_array_equal(out["up"][0], bp)
        np.testing.assert_array_equal(out["up"][1], bi)
    if topk is not None:
        vals, idx = srpt_topk_ref(*topk)
        np.testing.assert_array_equal(out["topk"][0], vals)
        np.testing.assert_array_equal(out["topk"][1], idx)


# ----------------------------------------------- shared pad-and-tile -------

@pytest.mark.parametrize("H,C,Hp,Cp", [
    (1, 1, 8, 128),        # minimum pads up to one full tile
    (8, 128, 8, 128),      # exact multiples pass through
    (13, 100, 16, 128),    # ragged both ways
    (16, 1000, 16, 1024),  # cols round up to the 128 multiple
])
def test_pad_tiles_rounds_to_tpu_tile(H, C, Hp, Cp):
    """Rows pad to the 8-sublane multiple, columns to the 128-lane
    multiple — the policy every kernel wrapper shares."""
    a = jnp.zeros((H, C), jnp.int32)
    (p,), (bh, bc) = dispatch.pad_tiles((a,), (BIG,))
    assert p.shape == (Hp, Cp)
    assert Hp % 8 == 0 and Cp % 128 == 0
    # block sizes tile the padded dims exactly
    assert Hp % bh == 0 and Cp % bc == 0


def test_pad_tiles_fill_values_per_array():
    """Each array pads with its own can't-win sentinel."""
    prio = jnp.ones((3, 5), jnp.int32)
    seq = jnp.full((3, 5), 7, jnp.int32)
    elig = jnp.ones((3, 5), bool)
    (pp, sp, ep), _ = dispatch.pad_tiles((prio, seq, elig),
                                         (BIG, BIG, False))
    assert int(pp[0, 5]) == BIG and int(pp[3, 0]) == BIG
    assert int(sp[0, 5]) == BIG
    assert not bool(ep[0, 5]) and not bool(ep[3, 0])
    # original content survives
    np.testing.assert_array_equal(pp[:3, :5], prio)


def test_pad_tiles_col_pref_caps_block():
    a = jnp.zeros((8, 1024), jnp.int32)
    _, (_, bc256) = dispatch.pad_tiles((a,), (0,), col_pref=256)
    _, (_, bc512) = dispatch.pad_tiles((a,), (0,), col_pref=512)
    assert bc256 == 256 and bc512 == 512


def test_pad_min_cols_uses_neg_sentinel():
    """M < K widens with NEG — NOT zero: 0 is a legitimate (ineligible)
    key and must outrank padding so indices stay in-bounds."""
    keys = jnp.zeros((2, 3), jnp.int32)
    wide = dispatch.pad_min_cols(keys, 5)
    assert wide.shape == (2, 5)
    assert int(wide[0, 3]) == NEG and int(wide[1, 4]) == NEG
    # wide-enough input passes through untouched
    assert dispatch.pad_min_cols(keys, 3) is keys


def test_padded_wrappers_still_match_ref():
    """pallas_arbitrate / pallas_topk on top of the SHARED helper keep
    their original contracts (regression for the refactor)."""
    rng = np.random.default_rng(0)
    down = _drain_problem(rng, 13, 100)
    bp, bi = dispatch.pallas_arbitrate(*down, interpret=True)
    rbp, rbi = priority_arbiter_ref(*down)
    np.testing.assert_array_equal(bp, rbp)
    np.testing.assert_array_equal(bi, rbi)
    keys = _keys(rng, 5, 37)
    vals, idx = dispatch.pallas_topk(keys, 4, interpret=True)
    rv, ri = srpt_topk_ref(keys, 4)
    np.testing.assert_array_equal(vals, rv)
    np.testing.assert_array_equal(idx, ri)


# ---------------------------------------------------- fused edge cases -----

def test_fused_all_stages_random():
    rng = np.random.default_rng(1)
    down = _drain_problem(rng, 16, 256)
    up = _drain_problem(rng, 8, 64)
    keys = _keys(rng, 16, 300)
    out = dispatch.fused_slot(down=down, up=up, topk=(keys, 4),
                              interpret=True)
    _assert_stages(out, down=down, up=up, topk=(keys, 4))


def test_fused_all_ineligible_slots():
    """No eligible entry anywhere: drains return (BIG, 0), the grant set
    is empty — exactly the staged sentinels."""
    rng = np.random.default_rng(2)
    p, s, _ = _drain_problem(rng, 8, 128)
    none = jnp.zeros_like(p, bool)
    keys = jnp.zeros((8, 64), jnp.int32)
    out = dispatch.fused_slot(down=(p, s, none), up=(p, s, none),
                              topk=(keys, 3), interpret=True)
    _assert_stages(out, down=(p, s, none), up=(p, s, none),
                   topk=(keys, 3))
    assert bool((out["down"][0] == BIG).all())
    assert bool((out["down"][1] == 0).all())
    assert bool((out["topk"][1] == -1).all())


def test_fused_single_host_racks():
    """racks == n_hosts means one host per rack: every uplink row serves
    a single source, the smallest-U shape the fabric can produce."""
    rng = np.random.default_rng(3)
    down = _drain_problem(rng, 8, 256)
    up = _drain_problem(rng, 8, 32, frac=0.15)   # U = racks * 1 uplink
    out = dispatch.fused_slot(down=down, up=up, interpret=True)
    _assert_stages(out, down=down, up=up)


@pytest.mark.parametrize("cap", [1, 37, 100, 129])
def test_fused_cap_not_block_multiple(cap):
    rng = np.random.default_rng(cap)
    down = _drain_problem(rng, 5, cap)
    out = dispatch.fused_slot(down=down, interpret=True)
    _assert_stages(out, down=down)


def test_fused_k_exceeds_eligible():
    """K larger than the eligible message count (and than M itself):
    surplus ranks come back (0, -1), like the staged kernel."""
    rng = np.random.default_rng(5)
    keys = _keys(rng, 4, 6, frac=0.4)
    out = dispatch.fused_slot(topk=(keys, 9), interpret=True)
    _assert_stages(out, topk=(keys, 9))
    n_elig = np.asarray((keys > 0).sum(axis=1))
    got_valid = np.asarray((out["topk"][0] > 0).sum(axis=1))
    np.testing.assert_array_equal(got_valid, n_elig)


def test_fused_empty_grant_set():
    """A slot where no receiver has anything to grant (all keys 0)."""
    keys = jnp.zeros((8, 128), jnp.int32)
    out = dispatch.fused_slot(topk=(keys, 4), interpret=True)
    _assert_stages(out, topk=(keys, 4))
    assert bool((out["topk"][0] == 0).all())
    assert bool((out["topk"][1] == -1).all())


def test_fused_vmem_fallback_bit_identical(monkeypatch):
    """Oversized operands fall back to the staged kernels — same
    answers, enforced by shrinking the limit to force the fallback."""
    rng = np.random.default_rng(6)
    down = _drain_problem(rng, 8, 256)
    keys = _keys(rng, 8, 100)
    want = dispatch.fused_slot(down=down, topk=(keys, 3), interpret=True)
    monkeypatch.setattr(dispatch, "FUSED_VMEM_LIMIT_BYTES", 1)
    got = dispatch.fused_slot(down=down, topk=(keys, 3), interpret=True)
    for stage in ("down", "topk"):
        np.testing.assert_array_equal(want[stage][0], got[stage][0])
        np.testing.assert_array_equal(want[stage][1], got[stage][1])


# ------------------------------------------------------- batched variant ---

def test_fused_batch_matches_single_and_vmap():
    """fused_slot_batch == vmap(fused_slot) == per-element single calls,
    and the vmap actually routes through the batched ``grid=(B,)``
    kernel (the custom_vmap rewrite the sweep path depends on)."""
    rng = np.random.default_rng(7)
    B, H, C, M, K = 5, 8, 128, 64, 3
    prio = jnp.asarray(rng.integers(0, 8, (B, H, C)), jnp.int32)
    seq = jnp.asarray(rng.integers(0, 4096, (B, H, C)), jnp.int32)
    elig = jnp.asarray(rng.random((B, H, C)) < 0.3)
    keys = jnp.asarray(
        np.where(rng.random((B, H, M)) < 0.5,
                 rng.integers(1, 1 << 20, (B, H, M)), 0), jnp.int32)

    batched = fused.fused_slot_batch(down=(prio, seq, elig), keys=keys,
                                     K=K, interpret=True)

    calls = {"batch": 0}
    orig = fused._call_batch

    def counting(*a, **k):
        calls["batch"] += 1
        return orig(*a, **k)

    fused._fused_fn.cache_clear()
    try:
        fused._call_batch = counting
        vmapped = jax.vmap(lambda p, s, e, m: fused.fused_slot(
            down=(p, s, e), keys=m, K=K, interpret=True))(
                prio, seq, elig, keys)
    finally:
        fused._call_batch = orig
        fused._fused_fn.cache_clear()
    assert calls["batch"] >= 1, "vmap did not take the batched kernel"

    for a, b in zip(batched, vmapped):
        np.testing.assert_array_equal(a, b)
    for i in range(B):
        single = fused.fused_slot(down=(prio[i], seq[i], elig[i]),
                                  keys=keys[i], K=K, interpret=True)
        for a, s in zip(batched, single):
            np.testing.assert_array_equal(a[i], s)


def test_fused_batch_broadcasts_unbatched_operands():
    """custom_vmap rule broadcasts operands closed over the batch axis
    (e.g. a shared eligibility mask constant inside a vmapped trace)."""
    rng = np.random.default_rng(8)
    B, H, C = 3, 8, 128
    prio = jnp.asarray(rng.integers(0, 8, (B, H, C)), jnp.int32)
    shared_seq = jnp.asarray(rng.integers(0, 4096, (H, C)), jnp.int32)
    elig = jnp.asarray(rng.random((B, H, C)) < 0.4)
    out = jax.vmap(lambda p, e: fused.fused_slot(
        down=(p, shared_seq, e), interpret=True))(prio, elig)
    for i in range(B):
        bp, bi = priority_arbiter_ref(prio[i], shared_seq, elig[i])
        np.testing.assert_array_equal(out[0][i], bp)
        np.testing.assert_array_equal(out[1][i], bi)


# -------------------------------------------- end-to-end edge configs ------

def test_fused_sim_single_host_racks():
    """End-to-end: a fabric with one host per rack is bit-identical
    across reference and fused backends."""
    from repro.core import SimConfig, FabricConfig, simulate, make_messages
    tbl = make_messages("W2", n_hosts=8, load=0.7, n_messages=80,
                        slot_bytes=256, seed=9)
    fab = FabricConfig(racks=8, oversub=2.0, up_cap=64)
    res = {}
    for b in ("reference", "pallas_fused"):
        res[b] = simulate(SimConfig(protocol="homa", n_hosts=8,
                                    max_slots=1500, ring_cap=256,
                                    fabric=fab, backend=b), tbl)
    np.testing.assert_array_equal(res["reference"].completion,
                                  res["pallas_fused"].completion)
    np.testing.assert_array_equal(res["reference"].tor_up_q_max_bytes,
                                  res["pallas_fused"].tor_up_q_max_bytes)


def test_fused_zero_delay_falls_back_staged():
    """net_delay_slots=0 breaks the hoist precondition, so the fused
    backend must skip fusing the downlink stage (falling back to the
    staged kernel at its usual point) and stay bit-identical."""
    from repro.core import SimConfig, simulate, make_messages
    tbl = make_messages("W2", n_hosts=8, load=0.7, n_messages=60,
                        slot_bytes=256, seed=10)
    res = {}
    for b in ("reference", "pallas_fused"):
        res[b] = simulate(SimConfig(protocol="homa", n_hosts=8,
                                    max_slots=1200, ring_cap=256,
                                    net_delay_slots=0, backend=b), tbl)
    np.testing.assert_array_equal(res["reference"].completion,
                                  res["pallas_fused"].completion)
