"""Compute-backend tests (DESIGN.md §6, §11).

``SimConfig.backend="pallas"`` and ``"pallas_fused"`` must be
BIT-identical to the reference backend for every registered protocol,
fabric enabled and disabled. All legs pin against golden snapshots
(``tests/golden/fabric_disabled.json`` from PR 2 and
``fabric_enabled.json``), so a divergence fails even if the backends
drift together. The CI matrix additionally runs the whole tier-1 suite
under ``SIM_BACKEND=pallas`` and ``SIM_BACKEND=pallas_fused``, which
routes every simulator test in the repo through the kernels.
"""
import json
from pathlib import Path

import numpy as np
import pytest

from repro.core import (SimConfig, FabricConfig, SweepSpec, simulate,
                        run_sweep, make_messages)
from repro.kernels.arbiter import dispatch

GOLDEN = Path(__file__).parent / "golden"
ALL_PROTOS = ["homa", "basic", "phost", "pias", "pfabric", "ndp"]
BACKENDS = ["reference", "pallas", "pallas_fused"]
KERNEL_BACKENDS = ["pallas", "pallas_fused"]


@pytest.fixture(scope="module")
def disabled():
    return json.loads((GOLDEN / "fabric_disabled.json").read_text())


@pytest.fixture(scope="module")
def enabled():
    return json.loads((GOLDEN / "fabric_enabled.json").read_text())


def _table(meta):
    return make_messages(meta["workload"], n_hosts=meta["n_hosts"],
                         load=meta["load"], n_messages=meta["n_messages"],
                         slot_bytes=meta["slot_bytes"], seed=meta["seed"])


def _cfg(meta, proto, backend, fabric=None):
    return SimConfig(protocol=proto, n_hosts=meta["n_hosts"],
                     max_slots=meta["max_slots"], ring_cap=meta["ring_cap"],
                     fabric=fabric, backend=backend)


def _assert_matches(r, want, fabric: bool):
    assert [int(x) for x in r.completion] == want["completion"]
    assert r.lost_chunks == want["lost_chunks"]
    assert [int(x) for x in r.q_max_bytes] == want["q_max_bytes"]
    assert [int(x) for x in r.prio_drained_bytes] \
        == want["prio_drained_bytes"]
    if fabric:
        assert [int(x) for x in r.tor_up_q_max_bytes] \
            == want["tor_up_q_max_bytes"]
        assert r.tor_up_lost_chunks == want["tor_up_lost_chunks"]


# ------------------------------------------------ golden bit-identity ------

@pytest.mark.parametrize("backend", KERNEL_BACKENDS)
@pytest.mark.parametrize("proto", ALL_PROTOS)
def test_pallas_matches_disabled_golden(disabled, proto, backend):
    """Fabric OFF: the pallas AND pallas_fused backends reproduce the
    pre-fabric golden bit-for-bit for every protocol (acceptance
    criterion)."""
    meta, want = disabled["meta"], disabled["protocols"][proto]
    r = simulate(_cfg(meta, proto, backend), _table(meta))
    _assert_matches(r, want, fabric=False)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("proto", ALL_PROTOS)
def test_backends_match_enabled_golden(enabled, proto, backend):
    """Fabric ON (4 racks, 2:1 oversub): BOTH backends reproduce the
    fabric-enabled golden bit-for-bit — downlink drain, TOR uplink
    drain, and the receiver grant set all route through the backend."""
    meta, want = enabled["meta"], enabled["protocols"][proto]
    fab = FabricConfig(racks=meta["racks"], oversub=meta["oversub"],
                       up_cap=meta["up_cap"])
    r = simulate(_cfg(meta, proto, backend, fabric=fab), _table(meta))
    _assert_matches(r, want, fabric=True)


@pytest.mark.parametrize("backend", KERNEL_BACKENDS)
def test_pallas_sweep_bit_identical_to_reference(backend):
    """The kernel backends must survive run_sweep's vmap over tables:
    batched pallas/pallas_fused == sequential reference. (For the fused
    backend the vmap additionally swaps in the batched ``grid=(B,)``
    mega-kernel via ``custom_vmap`` — DESIGN.md §11.)"""
    tables = [make_messages("W2", n_hosts=8, load=0.6, n_messages=100,
                            slot_bytes=256, seed=s) for s in range(2)]
    ref_cfg = SimConfig(protocol="homa", n_hosts=8, max_slots=2000,
                        ring_cap=256, backend="reference")
    pal_cfg = SimConfig(protocol="homa", n_hosts=8, max_slots=2000,
                        ring_cap=256, backend=backend)
    seq = [simulate(ref_cfg, t) for t in tables]
    swe = run_sweep(pal_cfg, SweepSpec(tables=tables))
    for a, b in zip(seq, swe):
        np.testing.assert_array_equal(a.completion, b.completion)
        np.testing.assert_array_equal(a.q_max_bytes, b.q_max_bytes)


# ------------------------------------------------------ config plumbing ----

def test_backend_env_default(monkeypatch):
    monkeypatch.delenv("SIM_BACKEND", raising=False)
    assert SimConfig().backend == "reference"
    monkeypatch.setenv("SIM_BACKEND", "pallas")
    assert SimConfig().backend == "pallas"
    monkeypatch.setenv("SIM_BACKEND", "pallas_fused")
    assert SimConfig().backend == "pallas_fused"
    # an explicit argument beats the environment
    assert SimConfig(backend="reference").backend == "reference"


def test_unknown_backend_raises(monkeypatch):
    with pytest.raises(ValueError, match="unknown backend"):
        SimConfig(backend="cuda")
    monkeypatch.setenv("SIM_BACKEND", "not-a-backend")
    with pytest.raises(ValueError, match="SIM_BACKEND"):
        SimConfig()


def test_interpret_resolution(monkeypatch):
    import jax
    monkeypatch.delenv("SIM_PALLAS_INTERPRET", raising=False)
    on_tpu = jax.default_backend() == "tpu"
    assert dispatch.resolve_interpret(None) == (not on_tpu)
    assert dispatch.resolve_interpret(True) is True
    assert dispatch.resolve_interpret(False) is False
    monkeypatch.setenv("SIM_PALLAS_INTERPRET", "0")
    assert dispatch.resolve_interpret(None) is False
    monkeypatch.setenv("SIM_PALLAS_INTERPRET", "1")
    assert dispatch.resolve_interpret(None) is True
    # SimConfig resolves the mode to a concrete bool (a jit retrace key)
    assert SimConfig(backend="pallas").pallas_interpret is True
