"""Test-suite bootstrap.

The container may lack ``hypothesis``; rather than failing collection for
every module that imports it, install a minimal stub whose ``@given`` tests
skip at runtime. Property tests run for real wherever hypothesis exists.
"""
import sys
import types


try:  # pragma: no cover - depends on environment
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover
    import pytest

    hyp = types.ModuleType("hypothesis")
    strat = types.ModuleType("hypothesis.strategies")

    def given(*_a, **_k):
        def deco(fn):
            # plain zero-arg wrapper: @wraps would expose the strategy
            # parameters in the signature and pytest would demand fixtures
            def skipper():
                pytest.skip("hypothesis not installed")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*_a, **_k):
        def deco(fn):
            return fn
        return deco

    def _strategy(*_a, **_k):
        return None

    hyp.given = given
    hyp.settings = settings
    hyp.strategies = strat
    for name in ("integers", "floats", "booleans", "sampled_from", "lists",
                 "text", "tuples", "one_of", "just"):
        setattr(strat, name, _strategy)
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strat
