"""Leaf-spine fabric subsystem + scenario library tests (DESIGN.md §5).

The bit-identity tests compare against ``tests/golden/fabric_disabled.json``,
a snapshot of the pre-fabric simulator's outputs: the fabric tier must be
invisible unless explicitly enabled.
"""
import json
from pathlib import Path

import numpy as np
import pytest

from repro.core import (SimConfig, FabricConfig, SweepSpec, simulate,
                        run_sweep, make_messages, scenarios)

GOLDEN = Path(__file__).parent / "golden" / "fabric_disabled.json"
ALL_PROTOS = ["homa", "basic", "phost", "pias", "pfabric", "ndp"]


@pytest.fixture(scope="module")
def golden():
    return json.loads(GOLDEN.read_text())


def _golden_table(meta):
    return make_messages(meta["workload"], n_hosts=meta["n_hosts"],
                         load=meta["load"], n_messages=meta["n_messages"],
                         slot_bytes=meta["slot_bytes"], seed=meta["seed"])


def _golden_cfg(meta, proto, **kw):
    return SimConfig(protocol=proto, n_hosts=meta["n_hosts"],
                     max_slots=meta["max_slots"], ring_cap=meta["ring_cap"],
                     **kw)


# ------------------------------------------------- disabled = bit-identical

@pytest.mark.parametrize("proto", ALL_PROTOS)
def test_fabric_disabled_bit_identical_to_golden(golden, proto):
    """With fabric disabled (the default), every protocol reproduces the
    pre-fabric simulator bit-for-bit."""
    meta, want = golden["meta"], golden["protocols"][proto]
    r = simulate(_golden_cfg(meta, proto), _golden_table(meta))
    assert [int(x) for x in r.completion] == want["completion"]
    assert r.lost_chunks == want["lost_chunks"]
    assert [int(x) for x in r.q_max_bytes] == want["q_max_bytes"]
    assert [int(x) for x in r.prio_drained_bytes] \
        == want["prio_drained_bytes"]
    assert r.fabric is None and r.tor_up_busy_frac is None


def test_fabric_none_sentinel_equals_disabled(golden):
    """``FabricConfig(None)`` is the disabled sentinel — bit-identical to
    ``fabric=None``."""
    meta = golden["meta"]
    tbl = _golden_table(meta)
    a = simulate(_golden_cfg(meta, "homa"), tbl)
    b = simulate(_golden_cfg(meta, "homa", fabric=FabricConfig(None)), tbl)
    np.testing.assert_array_equal(a.completion, b.completion)
    np.testing.assert_array_equal(a.q_max_bytes, b.q_max_bytes)
    assert not FabricConfig(None).enabled
    assert b.fabric is None


def test_single_rack_fabric_is_single_switch(golden):
    """racks=1 leaves every flow intra-rack: the uplink tier exists but
    never queues, and results match the single switch exactly."""
    meta = golden["meta"]
    tbl = _golden_table(meta)
    a = simulate(_golden_cfg(meta, "homa"), tbl)
    b = simulate(_golden_cfg(meta, "homa", fabric=FabricConfig(racks=1)),
                 tbl)
    np.testing.assert_array_equal(a.completion, b.completion)
    assert float(b.tor_up_busy_frac.sum()) == 0.0
    assert b.fabric["racks"] == 1


# ------------------------------------------------------ fabric invariants

@pytest.mark.parametrize("proto", ALL_PROTOS)
def test_fabric_conservation(proto):
    """With the uplink tier in the path, chunks are still conserved:
    sent == received + buffered (either tier) + lost (either tier)."""
    tbl = make_messages("W3", n_hosts=12, load=0.7, n_messages=250,
                        slot_bytes=256, seed=3)
    cfg = SimConfig(protocol=proto, n_hosts=12, max_slots=5000,
                    ring_cap=512, fabric=FabricConfig(racks=3, oversub=2.0))
    r = simulate(cfg, tbl, return_state=True)
    st = r.state
    assert int(st["recv"].sum()) + int(st["r_valid"].sum()) \
        + int(st["u_valid"].sum()) + int(st["lost"]) + int(st["u_lost"]) \
        == int(st["sent"].sum())
    done = st["completion"] >= 0
    assert (st["completion"][done] >= r.static["arrival"][done]).all()
    assert done.sum() > 0


def test_oversubscription_queues_uplinks():
    """An all-to-all shuffle through a tighter oversubscription ratio
    must queue more at the TOR uplinks, and the per-tier stats must
    surface in summary()/to_json()."""
    tbl = scenarios.shuffle(n_hosts=16, bytes_per_pair=10_000,
                            spread_slots=2000, seed=1)
    qmax = {}
    for ovs in (1.0, 4.0):
        cfg = SimConfig(protocol="homa", n_hosts=16, max_slots=12_000,
                        ring_cap=1024,
                        fabric=FabricConfig(racks=4, oversub=ovs,
                                            up_cap=2048))
        r = simulate(cfg, tbl)
        qmax[ovs] = int(r.tor_up_q_max_bytes.max())
        s = json.loads(r.to_json())
        assert s["fabric"]["oversub"] == ovs
        assert s["fabric"]["n_uplinks"] == max(1, round(4 / ovs))
        assert set(s["fabric"]) >= {"racks", "up_busy_frac",
                                    "up_q_mean_bytes", "up_q_max_bytes",
                                    "up_lost_chunks"}
    assert qmax[4.0] > qmax[1.0], qmax


def test_spine_selection_deterministic_and_seeded():
    import warnings
    from repro.core.fabric import spine_hash
    src = np.arange(64) % 16
    dst = (np.arange(64) * 7 + 1) % 16
    ids = np.arange(64)
    a = spine_hash(src, dst, ids, seed=0, n_uplinks=4)
    b = spine_hash(src, dst, ids, seed=0, n_uplinks=4)
    np.testing.assert_array_equal(a, b)
    assert ((0 <= a) & (a < 4)).all()
    assert (a != spine_hash(src, dst, ids, seed=1, n_uplinks=4)).any()
    with warnings.catch_warnings():
        warnings.simplefilter("error")      # wraparound must be silent
        spine_hash(src, dst, ids, seed=100, n_uplinks=4)
    # and whole runs are reproducible / seed-sensitive
    tbl = scenarios.shuffle(n_hosts=8, bytes_per_pair=20_000, seed=0)
    fab = FabricConfig(racks=4, oversub=2.0)
    cfg = SimConfig(protocol="homa", n_hosts=8, max_slots=6000,
                    ring_cap=512, fabric=fab)
    r1, r2 = simulate(cfg, tbl), simulate(cfg, tbl)
    np.testing.assert_array_equal(r1.completion, r2.completion)


def test_fabric_composes_with_run_sweep():
    """The fabric stage must ride inside the vmapped sweep unchanged:
    batched results are bit-identical to sequential simulate calls."""
    fab = FabricConfig(racks=4, oversub=2.0)
    cfg = SimConfig(protocol="homa", n_hosts=16, max_slots=3000,
                    ring_cap=256, fabric=fab)
    tables = [make_messages("W2", n_hosts=16, load=0.6, n_messages=120,
                            slot_bytes=256, seed=s) for s in range(3)]
    seq = [simulate(cfg, t) for t in tables]
    swe = run_sweep(cfg, SweepSpec(tables=tables))
    for a, b in zip(seq, swe):
        np.testing.assert_array_equal(a.completion, b.completion)
        np.testing.assert_array_equal(a.tor_up_q_max_bytes,
                                      b.tor_up_q_max_bytes)
        assert a.tor_up_lost_chunks == b.tor_up_lost_chunks


def test_nondefault_delays_keep_slowdown_anchored():
    """Slowdown's unloaded baseline must track the fabric's cross-rack
    delay budget, not net_delay_slots, when they differ."""
    from repro.core.workloads import MessageTable
    # one sparse cross-rack message (src rack 0 -> dst rack 1)
    tbl = MessageTable(np.array([0], np.int32), np.array([4], np.int32),
                       np.array([256], np.int64), np.array([0], np.int32),
                       "custom", 0.0, 256)
    cfg = SimConfig(protocol="homa", n_hosts=8, max_slots=400,
                    ring_cap=64,
                    fabric=FabricConfig(racks=2, leaf_delay_slots=20,
                                        spine_delay_slots=20))
    r = simulate(cfg, tbl)
    assert r.done.all()
    np.testing.assert_allclose(r.slowdown[0], 1.0, atol=0.05)


def test_fabric_validation_errors():
    with pytest.raises(ValueError, match="divisible"):
        SimConfig(n_hosts=10, fabric=FabricConfig(racks=3))
    with pytest.raises(ValueError, match="oversub"):
        SimConfig(n_hosts=8, fabric=FabricConfig(racks=2, oversub=0))
    with pytest.raises(ValueError, match="spine_delay"):
        SimConfig(n_hosts=8, fabric=FabricConfig(racks=2,
                                                 spine_delay_slots=0))
    with pytest.raises(ValueError, match="racks"):
        SimConfig(n_hosts=8, fabric=FabricConfig(racks=0))


# -------------------------------------------------- acceptance: Fig. 14

def test_incast_on_oversubscribed_fabric_homa_beats_basic():
    """Fig. 14 shape on a 2:1-oversubscribed leaf-spine: repeated fan-in
    bursts + Poisson background; Homa's priorities keep small messages'
    p99 slowdown far below basic's."""
    tbl = scenarios.incast(12, 2048, n_hosts=16, n_bursts=8,
                           period_slots=1500, background="W2",
                           background_load=0.5, n_background=600, seed=2)
    p99 = {}
    for proto in ("homa", "basic"):
        cfg = SimConfig(protocol=proto, n_hosts=16, max_slots=16_000,
                        ring_cap=1024,
                        fabric=FabricConfig(racks=4, oversub=2.0,
                                            up_cap=1024))
        r = simulate(cfg, tbl)
        assert r.n_complete == r.n_messages, (proto, r.n_complete)
        small = r.steady_mask() & (r.size_bytes < 1000)
        p99[proto] = r.percentile(99, small)
    assert p99["homa"] * 2 < p99["basic"], p99


# ------------------------------------------------------ scenario library

def test_incast_table_structure():
    t = scenarios.incast(10, 4096, n_hosts=16, dst=3, n_bursts=2,
                         period_slots=500)
    assert len(t.size) == 20
    assert (t.dst == 3).all()
    assert (t.src != 3).all()
    assert (t.size == 4096).all()
    assert sorted(set(t.arrival_slot)) == [0, 500]
    for slot in (0, 500):
        burst = t.src[t.arrival_slot == slot]
        assert len(set(burst.tolist())) == 10       # distinct senders
    with pytest.raises(ValueError, match="fan_in"):
        scenarios.incast(16, 1000, n_hosts=16)


def test_hotspot_skews_destinations():
    t = scenarios.hotspot("W2", n_hosts=16, load=0.6, n_messages=400,
                          hot_fraction=0.6, n_hot=2, seed=0)
    hot = np.isin(t.dst, [0, 1]).mean()
    assert hot > 0.45                                # vs 2/16 uniform
    assert (t.src != t.dst).all()
    base = make_messages("W2", n_hosts=16, load=0.6, n_messages=400,
                         slot_bytes=256, seed=0)
    np.testing.assert_array_equal(t.size, base.size)  # sizes untouched
    with pytest.raises(ValueError, match="hot_fraction"):
        scenarios.hotspot("W2", n_hosts=16, load=0.6, n_messages=10,
                          hot_fraction=1.5)


def test_shuffle_covers_all_pairs():
    t = scenarios.shuffle(n_hosts=6, bytes_per_pair=5000)
    assert len(t.size) == 30
    pairs = set(zip(t.src.tolist(), t.dst.tolist()))
    assert len(pairs) == 30 and all(s != d for s, d in pairs)
    assert (t.size == 5000).all()
    assert (t.arrival_slot == 0).all()
    t2 = scenarios.shuffle(n_hosts=6, bytes_per_pair=5000,
                           spread_slots=100, seed=4)
    assert t2.arrival_slot.max() < 100 and len(set(t2.arrival_slot)) > 1


# ------------------------------------------- satellites: wiring + errors

def test_make_messages_incast_param_changes_table():
    """Regression: the ``incast`` parameter used to be accepted and
    silently ignored."""
    kw = dict(n_hosts=8, load=0.5, n_messages=200, slot_bytes=256, seed=0)
    plain = make_messages("W2", **kw)
    with_incast = make_messages("W2", incast=(5, 4096, 300), **kw)
    assert len(with_incast.size) > len(plain.size)
    burst = with_incast.size == 4096
    assert burst.sum() >= 5
    assert (with_incast.dst[burst] == 0).all()
    # background stream is preserved underneath the overlay
    assert np.isin(plain.size, with_incast.size).all()
    # and arrivals remain sorted so the simulator's warmup mask is valid
    assert (np.diff(with_incast.arrival_slot) >= 0).all()
    with pytest.raises(ValueError, match="period_slots"):
        make_messages("W2", incast=(5, 4096, 0), **kw)


def test_prepare_rejects_oversized_inputs_with_valueerror():
    """Satellite: the MSG_MOD / max_slots guards must survive
    ``python -O`` (they were asserts)."""
    from repro.core.protocols import MSG_MOD
    from repro.core.workloads import MessageTable
    n = MSG_MOD + 1
    tbl = MessageTable(np.zeros(n, np.int32), np.ones(n, np.int32),
                       np.full(n, 100, np.int64), np.zeros(n, np.int32),
                       "custom", 0.0, 256)
    with pytest.raises(ValueError, match="at most"):
        simulate(SimConfig(n_hosts=4, max_slots=100), tbl)
    small = make_messages("W1", n_hosts=4, load=0.5, n_messages=10,
                          slot_bytes=256, seed=0)
    with pytest.raises(ValueError, match="max_slots"):
        simulate(SimConfig(n_hosts=4, max_slots=2 ** 21), small)
