"""Generate the data-driven sections of EXPERIMENTS.md from artifacts/.

    PYTHONPATH=src python scripts/report.py > artifacts/report.md
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
BENCH = ROOT / "artifacts" / "bench"
DRY = ROOT / "artifacts" / "dryrun"


def j(name, d=BENCH):
    p = d / name
    return json.loads(p.read_text()) if p.exists() else None


def paper_validation():
    print("### Paper-validation table (measured)\n")
    rows = []
    fig12 = j("fig12_slowdown.json")
    if fig12:
        homa = [r for r in fig12 if r["protocol"] == "homa"
                and r["load"] == 0.8]
        small = [r["p99_slowdown"] for r in homa if r["size_bytes"] < 1500]
        rows.append(("Homa 99p slowdown small msgs @80%", "<= ~2.2-3.5",
                     f"{max(small):.2f} (max over small buckets)" if small
                     else "n/a"))
        basic = [r for r in fig12 if r["protocol"] == "basic"]
        hb = [(b["p99_slowdown"], h["p99_slowdown"])
              for b in basic for h in homa
              if b["workload"] == h["workload"]
              and b["size_bytes"] == h["size_bytes"]
              and h["size_bytes"] < 1500]
        if hb:
            ratios = [b / max(h, 1e-9) for b, h in hb]
            rows.append(("Basic/Homa tail ratio (small)", "5-15x",
                         f"{min(ratios):.1f}-{max(ratios):.1f}x"))
        pf = [r for r in fig12 if r["protocol"] == "pfabric"]
        hp = [(p_["p99_slowdown"], h["p99_slowdown"]) for p_ in pf
              for h in homa if p_["workload"] == h["workload"]
              and p_["size_bytes"] == h["size_bytes"]]
        if hp:
            import statistics
            r_ = [h / max(p_, 1e-9) for p_, h in hp]
            rows.append(("Homa vs pFabric 99p", "~equal",
                         f"median ratio {statistics.median(r_):.2f}"))
    fig16 = j("fig16_wasted_bandwidth.json")
    if fig16:
        k1 = [r for r in fig16 if r["overcommit"] == 1 and r["load"] >= 0.8]
        k7 = [r for r in fig16 if r["overcommit"] == 7 and r["load"] >= 0.8]
        if k1 and k7:
            rows.append(("Wasted bw @>=80% load, K=1 vs K=7",
                         "K=1 wastes much more (Fig 16)",
                         f"{k1[0]['wasted_frac']:.3f} vs "
                         f"{k7[0]['wasted_frac']:.3f}"))
    f15 = j("fig15_utilization.json")
    if f15:
        by = {r["protocol"]: r["max_sustainable_load"] for r in f15}
        rows.append(("Max sustainable load (W3, 8 hosts)",
                     "differentiation needs W4/W5+144 hosts (see notes)",
                     str(by)))
    t1 = j("table1_queues.json")
    if t1:
        rows.append(("Queue mean/max (KB)", "mean 1-17, max ~146 (Table 1)",
                     "; ".join(f"{r['workload']}: {r['q_mean_kb']}/"
                               f"{r['q_max_kb']}" for r in t1)))
    f10 = j("fig10_incast.json")
    if f10:
        ctl = [r for r in f10 if r["incast_control"]]
        rows.append(("Incast w/ control", "no loss, bounded buffers",
                     "; ".join(f"n={r['n_rpcs']}: lost={r['lost_chunks']} "
                               f"qmax={r['q_max_kb']}KB" for r in ctl)))
    f17 = j("fig17_unsched_prios.json")
    if f17:
        rows.append(("W1: unsched prios 1 vs 2 vs 7 (p99 small)",
                     ">2.5x worse with 1 (Fig 17)",
                     "; ".join(f"{r['n_unsched']}: {r['p99_small']:.2f}"
                               for r in f17)))
    f19 = j("fig19_sched_prios.json")
    if f19:
        rows.append(("W4: sched prios (completion@80%)",
                     "needs >=4 (Fig 19)",
                     "; ".join(f"K={r['n_sched']}: {r['completion']}"
                               for r in f19)))
    f18 = j("fig18_cutoffs.json")
    if f18:
        rows.append(("W3 cutoff sweep p99(all)", "~1930B best (Fig 18)",
                     "; ".join(f"{r['cutoff']}B: {r['p99_all']:.2f}"
                               for r in f18)))
    f14 = j("fig14_preemption_lag.json")
    if f14:
        rows.append(("Preemption-lag (slot granularity) p99 small",
                     "finer slots -> lower tail (Fig 14 analogue)",
                     "; ".join(f"{r['slot_bytes']}B: {r['p99_small']:.2f}"
                               for r in f14)))
    fo = j("fabric_oversub.json")
    if fo:
        # key by (oversub, load): full mode emits several loads per ratio
        homa = {(r["oversub"], r["load"]): r for r in fo
                if r["protocol"] == "homa"}
        basic = {(r["oversub"], r["load"]): r for r in fo
                 if r["protocol"] == "basic"}
        rows.append(("Leaf-spine oversub sweep (p99 small, homa vs basic)",
                     "homa flat, basic degrades with oversub (§5.2)",
                     "; ".join(f"{o}:1@{ld} -> {homa[o, ld]['p99_small']} "
                               f"vs {basic[o, ld]['p99_small']}"
                               for o, ld in sorted(homa)
                               if (o, ld) in basic)))
        rows.append(("TOR uplink queue max (homa)", "grows with oversub",
                     "; ".join(f"{o}:1@{ld}: "
                               f"{homa[o, ld]['up_q_max_kb']}KB"
                               for o, ld in sorted(homa))))
    fi = j("fig14_fabric_incast.json")
    if fi:
        hw = [r for r in fi if r["protocol"] == "homa"]
        bw = {r["fan_in"]: r for r in fi if r["protocol"] == "basic"}
        rows.append(("Fabric incast (Fig 14 shape, 2:1 oversub)",
                     "homa p99 small << basic at every fan-in",
                     "; ".join(f"n={r['fan_in']}: {r['p99_small']} vs "
                               f"{bw[r['fan_in']]['p99_small']}"
                               for r in hw if r["fan_in"] in bw)))
    ff = j("fig_faults.json")
    if ff:
        by = {(r["protocol"], r["scenario"], r["routing"],
               r["up_loss"]): r for r in ff}
        loss_rates = sorted({r["up_loss"] for r in ff
                             if r["scenario"] == "loss"})
        rows.append(("Resilience: p99 small vs uplink loss "
                     "(homa vs basic, ECMP)",
                     "homa degrades gracefully, stays below basic (§3.7)",
                     "; ".join(
                         f"{lr:g}: {by['homa', 'loss', 'ecmp', lr]['p99_small']}"
                         f" vs {by['basic', 'loss', 'ecmp', lr]['p99_small']}"
                         for lr in loss_rates)))
        rows.append(("Resilience: mean recovery slots vs loss "
                     "(homa vs basic)",
                     "receiver RESEND beats sender fallback",
                     "; ".join(
                         f"{lr:g}: {by['homa', 'loss', 'ecmp', lr]['recovery_mean']}"
                         f" vs {by['basic', 'loss', 'ecmp', lr]['recovery_mean']}"
                         for lr in loss_rates if lr > 0)))
        rows.append(("Resilience: uplink-failure window, p99 small by "
                     "routing (homa)",
                     "adaptive < flowlet < ecmp (RepFlow point)",
                     "; ".join(
                         f"{rt}: {by['homa', 'linkfail', rt, 0.0]['p99_small']}"
                         f" (lost={by['homa', 'linkfail', rt, 0.0]['fault_lost']})"
                         for rt in ("ecmp", "flowlet", "adaptive")
                         if ("homa", "linkfail", rt, 0.0) in by)))
    hm = j("fig_hostmodel.json")
    if hm:
        by = {(r["workload"], r["host"]): r for r in hm}
        wls = sorted({r["workload"] for r in hm})
        rows.append(("Host model: p50 slowdown gap vs ideal host "
                     "(kernel_bypass / kernel_stack)",
                     "sim-vs-implementation gap is a host artifact, "
                     "monotone in per-packet cost (§5.3)",
                     "; ".join(
                         f"{w}: {by[w, 'kernel_bypass']['gap_p50']}x / "
                         f"{by[w, 'kernel_stack']['gap_p50']}x"
                         for w in wls
                         if (w, "kernel_stack") in by)))
        rows.append(("Host model: kernel-stack TX busy / RX backlog",
                     "host, not fabric, is the bottleneck at high load",
                     "; ".join(
                         f"{w}: busy={by[w, 'kernel_stack']['tx_busy']}, "
                         f"rxq_max={by[w, 'kernel_stack']['rx_q_max']}"
                         for w in wls
                         if (w, "kernel_stack") in by)))
    ts = j("trace_smoke.json")
    if ts:
        r = ts[0]
        rows.append(("Telemetry: capture overhead (traced vs untraced "
                     "scan execute)",
                     "< 20% slot-rate regression (DESIGN §8)",
                     f"{r['overhead_pct']}% ({r['exec_on_s']}s vs "
                     f"{r['exec_off_s']}s over {r['slots']} slots)"))
        rows.append(("Telemetry: event-ledger occupancy",
                     "bounded capture; overflow counted, never grown",
                     f"{r['n_events']}/{r['n_events_seen']} rows kept "
                     f"(dropped {r['events_dropped']}, cap via "
                     f"TraceConfig.ledger_cap); {r['samples']} series "
                     f"samples @ stride {r['stride']}"))
        rows.append(("Telemetry: AOT wall-clock split "
                     "(trace/compile/execute)",
                     "execute dominates at bench scale",
                     f"{r['aot_trace_s']}s / {r['aot_compile_s']}s / "
                     f"{r['aot_execute_s']}s"))
    sw = j("sweep_speed.json")
    if sw:
        batch = [r for r in sw if r.get("kind", "batch") == "batch"]
        if batch:
            rows.append(("run_sweep vs sequential simulate (8 seeds)",
                         "< 0.5x wall time, one jit trace",
                         "; ".join(f"{r['protocol']}/{r['workload']}: "
                                   f"{r['sweep_s']}s vs "
                                   f"{r['sequential_s']}s "
                                   f"({r['ratio']}x)" for r in batch)))
        for r in (r for r in sw if r.get("kind") == "mega"):
            rows.append(("Sharded mega-sweep (6 proto x 3 load x 4 seed, "
                         "streaming stats)",
                         "linear scale-out across devices",
                         f"{r['n_runs']} runs on {r['n_devices']} "
                         f"device(s) in {r['mega_s']}s = "
                         f"{r['runs_per_sec_per_device']} runs/s/device; "
                         f"{r['completions']} completions"))
    cs = j("collective_predicted.json")
    if cs:
        rows.append(("Grad-sync predicted (SRPT senders)",
                     "small chunks unblocked (paper 2.2)",
                     "; ".join(f"{r['mode']}/{r['protocol']}: small p99="
                               f"{r['small_chunk_p99_slowdown']}"
                               for r in cs)))
    print("| claim | paper | measured |")
    print("|---|---|---|")
    for a, b, c in rows:
        print(f"| {a} | {b} | {c} |")
    print()


def dryrun_summary():
    print("### Dry-run summary\n")
    ok = {"16x16": 0, "2x16x16": 0}
    skipped = 0
    worst = []
    for f in sorted(DRY.glob("*.json")):
        if "__unrolled" in f.name or f.name.startswith("BASE__"):
            continue
        d = json.loads(f.read_text())
        if d["status"] == "skipped":
            skipped += 1
            continue
        if d["status"] == "ok":
            ok[d["mesh"]] += 1
    print(f"- compiled OK: {ok['16x16']} cells on 16x16, "
          f"{ok['2x16x16']} on 2x16x16; skipped {skipped // 1} "
          f"(long_500k on full-attention archs, DESIGN §4)\n")


def roofline_table():
    sys.path.insert(0, str(ROOT))
    sys.path.insert(0, str(ROOT / "src"))
    from benchmarks.roofline import analyze_cell
    from repro.configs import ARCH_NAMES
    from repro.configs.base import SHAPES, cell_is_skipped
    print("### Roofline (single-pod 16x16; seconds/step/device)\n")
    print("| arch | shape | compute | memory | collective | dominant | "
          "frac | useful | HBM GB | fits16 | source |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for a in ARCH_NAMES:
        for s in SHAPES:
            if cell_is_skipped(a, s):
                continue
            r = analyze_cell(a, s, "16x16")
            if not r:
                continue
            print(f"| {a} | {s} | {r['t_compute_s']:.3g} | "
                  f"{r['t_memory_s']:.3g} | {r['t_collective_s']:.3g} | "
                  f"{r['dominant']} | {r['roofline_frac']:.3f} | "
                  f"{(r['useful_ratio'] or 0):.2f} | "
                  f"{r['hbm_resident_gb']:.1f} | "
                  f"{'Y' if r['fits_hbm16'] else 'N'} | {r['source']} |")
    print()


def perf_ab():
    print("### §Perf A/B raw numbers\n")

    def tot(base_prefix):
        nb1 = j(f"{base_prefix}__nb1.json", DRY)
        nb2 = j(f"{base_prefix}__nb2.json", DRY)
        if not (nb1 and nb2):
            return None
        nbf = nb1["n_scan_blocks_full"]

        def ex(key, sub=None):
            a = nb1["cost"][key] if sub is None else nb1[sub]["total_bytes"]
            b = nb2["cost"][key] if sub is None else nb2[sub]["total_bytes"]
            return (a - (b - a)) + (b - a) * nbf
        return dict(flops=ex("flops"), bytes=ex("bytes accessed"),
                    coll=ex(None, "collectives"))

    for cell in ("llama3.2-3b__train_4k", "deepseek-v2-lite-16b__train_4k",
                 "llama3-405b__train_4k"):
        b = tot(f"BASE__{cell}")
        o = tot(f"{cell}__16x16__unrolled")
        if b and o:
            print(f"- {cell}:")
            for k, unit, div in (("flops", "TF", 1e12), ("bytes", "TB", 1e12),
                                 ("coll", "GB", 1e9)):
                print(f"    {k}: {b[k]/div:.1f} -> {o[k]/div:.1f} {unit} "
                      f"({b[k]/max(o[k],1e-9):.2f}x)")
    mo = j("llama3-405b__train_4k__16x16__memopt.json", DRY)
    bo = j("llama3-405b__train_4k__16x16.json", DRY)
    if mo and bo:
        g = lambda d: (d["memory"]["argument_size_in_bytes"]
                       + d["memory"]["temp_size_in_bytes"]) / 1e9
        print(f"- llama3-405b mem-opt: resident {g(bo):.1f} -> {g(mo):.1f} GB"
              f" (fits 16GB: {g(mo) <= 16})")
    print()


if __name__ == "__main__":
    paper_validation()
    dryrun_summary()
    roofline_table()
    perf_ab()
