"""Measurement sweep: depth-reduced unrolled cells (nb=1,2) per non-skipped
(arch x shape) on the single-pod mesh, for exact-affine extrapolation of
FLOPs / bytes / collective bytes (see benchmarks/roofline.py)."""
import os, subprocess, sys, json
sys.path.insert(0, "src")
from repro.configs import ARCH_NAMES
from repro.configs.base import SHAPES, cell_is_skipped

cells = [(a, s) for a in ARCH_NAMES for s in SHAPES if not cell_is_skipped(a, s)]
fails = []
for a, s in cells:
    for nb in (1, 2):
        out = f"artifacts/dryrun/{a}__{s}__16x16__unrolled__nb{nb}.json"
        if os.path.exists(out):
            print("[cached]", out); continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", a,
               "--shape", s, "--unroll", "--nblocks", str(nb)]
        print("[run]", a, s, "nb", nb, flush=True)
        r = subprocess.run(cmd, capture_output=True, text=True, timeout=3000,
                           env={**os.environ, "PYTHONPATH": "src"})
        if r.returncode != 0:
            fails.append((a, s, nb)); print(r.stderr[-500:])
print("failures:", fails)
