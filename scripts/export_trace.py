"""Run one traced simulation and export its timeline (DESIGN.md §8).

    PYTHONPATH=src python scripts/export_trace.py \
        --protocol homa --workload W2 --load 0.6 --out trace.json

Writes a Chrome trace-event / Perfetto JSON (open it at
https://ui.perfetto.dev — counter tracks carry the strided queue /
grant / priority series, the "protocol events" process carries the
ledger as instant events per host, and the "messages" process shows
each completed message as a duration slice). ``--timeseries`` instead
writes the raw JSON time-series form (the bench-cache schema).

The quickstart lives in README.md ("Observability").
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.core import (SimConfig, FabricConfig, TraceConfig, simulate,
                        make_messages)
from repro.core.telemetry import EV_NAMES


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--protocol", default="homa")
    ap.add_argument("--workload", default="W2")
    ap.add_argument("--load", type=float, default=0.6)
    ap.add_argument("--n-hosts", type=int, default=16)
    ap.add_argument("--n-messages", type=int, default=600)
    ap.add_argument("--max-slots", type=int, default=10_000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--racks", type=int, default=None,
                    help="enable the leaf-spine fabric with this many "
                         "racks (default: single switch)")
    ap.add_argument("--oversub", type=float, default=2.0)
    ap.add_argument("--up-loss", type=float, default=0.0,
                    help="Bernoulli uplink chunk-loss rate (fabric only)")
    ap.add_argument("--stride", type=int, default=16,
                    help="slots per time-series sample window")
    ap.add_argument("--ledger-cap", type=int, default=4096)
    ap.add_argument("--timeseries", action="store_true",
                    help="write the JSON time-series form instead of "
                         "Perfetto")
    ap.add_argument("--out", default="trace.json")
    args = ap.parse_args()

    fabric = None
    if args.racks:
        faults = dict(up_loss=args.up_loss) if args.up_loss > 0 else None
        fabric = FabricConfig(racks=args.racks, oversub=args.oversub,
                              faults=faults)
    elif args.up_loss > 0:
        print("--up-loss needs --racks (losses live on the fabric tier)",
              file=sys.stderr)
        return 2

    cfg = SimConfig(n_hosts=args.n_hosts, protocol=args.protocol,
                    max_slots=args.max_slots, fabric=fabric,
                    trace=TraceConfig(stride=args.stride,
                                      ledger_cap=args.ledger_cap))
    tbl = make_messages(args.workload, n_hosts=args.n_hosts,
                        load=args.load, n_messages=args.n_messages,
                        slot_bytes=cfg.slot_bytes, seed=args.seed)
    r = simulate(cfg, tbl)
    tr = r.trace

    if args.timeseries:
        with open(args.out, "w") as f:
            json.dump(tr.to_timeseries_json(), f)
    else:
        tr.to_perfetto(args.out)

    kinds = {}
    for k in tr.events[:, 1].tolist():
        name = EV_NAMES.get(int(k), str(k))
        kinds[name] = kinds.get(name, 0) + 1
    print(f"wrote {args.out}: {r.n_complete}/{r.n_messages} messages, "
          f"{len(tr.sample_slots)} samples @ stride {tr.stride}, "
          f"{tr.n_events} ledger rows ({tr.events_dropped} dropped) "
          f"{kinds}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
