"""Regenerate the golden simulator snapshots under tests/golden/.

    PYTHONPATH=src python scripts/make_golden.py [--check]

Two snapshots, each pinning all six protocols on the REFERENCE backend:

  fabric_disabled.json   the pre-fabric single-switch simulator (PR 2) —
                         the fabric tier must stay invisible by default.
  fabric_enabled.json    a 4-rack 2:1-oversubscribed leaf-spine run —
                         pins the uplink tier AND anchors the pallas
                         backend's bit-identity tests (test_backend.py).

``--check`` regenerates in memory and fails (exit 1) on any drift
instead of rewriting — run it before committing simulator changes that
are supposed to be behaviour-preserving. The check pass runs each
snapshot on the reference backend AND on ``pallas_fused`` (interpret
mode): the fused mega-kernel (DESIGN.md §11) must reproduce both
committed goldens bit-for-bit, not just match the reference in tests.
Goldens are always WRITTEN from the reference backend only.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

from repro.core import SimConfig, FabricConfig, simulate, make_messages

GOLDEN_DIR = Path(__file__).resolve().parents[1] / "tests" / "golden"
PROTOS = ["homa", "basic", "phost", "pias", "pfabric", "ndp"]

DISABLED_META = dict(workload="W2", n_hosts=8, load=0.7, n_messages=300,
                     slot_bytes=256, seed=11, max_slots=4000, ring_cap=512)
ENABLED_META = dict(workload="W2", n_hosts=8, load=0.7, n_messages=250,
                    slot_bytes=256, seed=11, max_slots=3000, ring_cap=512,
                    racks=4, oversub=2.0, up_cap=256)


def _table(meta):
    return make_messages(meta["workload"], n_hosts=meta["n_hosts"],
                         load=meta["load"], n_messages=meta["n_messages"],
                         slot_bytes=meta["slot_bytes"], seed=meta["seed"])


def _snapshot(meta, fabric: FabricConfig | None,
              backend: str = "reference") -> dict:
    tbl = _table(meta)
    out = {}
    for proto in PROTOS:
        cfg = SimConfig(protocol=proto, n_hosts=meta["n_hosts"],
                        max_slots=meta["max_slots"],
                        ring_cap=meta["ring_cap"], fabric=fabric,
                        backend=backend)
        r = simulate(cfg, tbl)
        rec = {
            "completion": [int(x) for x in r.completion],
            "lost_chunks": int(r.lost_chunks),
            "q_max_bytes": [int(x) for x in r.q_max_bytes],
            "prio_drained_bytes": [int(x) for x in r.prio_drained_bytes],
            "busy": [round(float(x), 8) for x in r.busy_frac],
        }
        if fabric is not None and fabric.enabled:
            rec["tor_up_q_max_bytes"] = [int(x) for x
                                         in r.tor_up_q_max_bytes]
            rec["tor_up_lost_chunks"] = int(r.tor_up_lost_chunks)
        out[proto] = rec
    return {"meta": meta, "protocols": out}


def main() -> int:
    check = "--check" in sys.argv[1:]
    fabric = FabricConfig(racks=ENABLED_META["racks"],
                          oversub=ENABLED_META["oversub"],
                          up_cap=ENABLED_META["up_cap"])
    targets = {"fabric_disabled.json": None, "fabric_enabled.json": fabric}
    # the goldens are authored by the reference backend; --check also
    # replays them through the fused mega-kernel backend (DESIGN.md §11)
    backends = ["reference", "pallas_fused"] if check else ["reference"]
    rc = 0
    for name, fab in targets.items():
        fp = GOLDEN_DIR / name
        meta = ENABLED_META if fab is not None else DISABLED_META
        for backend in backends:
            snap = _snapshot(meta, fab, backend=backend)
            if check:
                if not fp.exists() or json.loads(fp.read_text()) != snap:
                    print(f"DRIFT: {fp} [{backend}]")
                    rc = 1
                else:
                    print(f"ok: {fp} [{backend}]")
            else:
                text = json.dumps(snap)
                fp.write_text(text)
                print(f"wrote {fp} ({len(text)} bytes)")
    return rc


if __name__ == "__main__":
    sys.exit(main())
