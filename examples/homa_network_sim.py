"""Run the paper-faithful Homa packet-level simulator and print a miniature
Figure-12: 99p slowdown by message size, for any registered protocols.

    PYTHONPATH=src python examples/homa_network_sim.py [--workload W3]
        [--protocols homa,basic,ndp]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import (SimConfig, simulate, registered_protocols,
                        make_messages)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="W3")
    ap.add_argument("--load", type=float, default=0.8)
    ap.add_argument("--messages", type=int, default=1500)
    ap.add_argument("--protocols", default="homa,basic",
                    help=f"comma-separated; registered: "
                         f"{','.join(registered_protocols())}")
    a = ap.parse_args()
    protos = a.protocols.split(",")

    tbl = make_messages(a.workload, n_hosts=8, load=a.load,
                        n_messages=a.messages, slot_bytes=256, seed=1)
    print(f"workload {a.workload} @ {a.load:.0%} load, "
          f"{a.messages} messages, 8 hosts")
    results = {}
    for proto in protos:
        cfg = SimConfig(n_hosts=8, protocol=proto, max_slots=60_000,
                        ring_cap=2048)          # unknown proto -> ValueError
        res = simulate(cfg, tbl)
        results[proto] = res
        b = res.percentiles_by_size(99, n_buckets=8)
        print(f"\n{proto}: {res.n_complete}/{res.n_messages} complete, "
              f"priorities: {res.alloc.n_unsched} unsched / "
              f"{res.alloc.n_sched} sched, cutoffs {res.alloc.cutoffs}")
        print("  size_bytes   p99_slowdown   median")
        for sz, p, m in zip(b["sizes"], b["p"], b["median"]):
            bar = "#" * min(int(p * 2), 60)
            print(f"  {int(sz):>9}   {p:>7.2f} {bar}")

    if "homa" in results and "basic" in results:
        h, bsc = results["homa"], results["basic"]
        ph = h.percentile(99, h.done & (h.size_bytes < 1000))
        pb = bsc.percentile(99, bsc.done & (bsc.size_bytes < 1000))
        if ph is None or pb is None:    # e.g. W5 has no sub-1KB messages
            print("\nno completed sub-1KB messages to compare")
        else:
            print(f"\nsmall-message p99: homa {ph:.2f} vs basic {pb:.2f} "
                  f"({pb / ph:.1f}x better)")


if __name__ == "__main__":
    main()
