"""Run the paper-faithful Homa packet-level simulator and print a miniature
Figure-12: 99p slowdown by message size, Homa vs Basic at 80% load.

    PYTHONPATH=src python examples/homa_network_sim.py [--workload W3]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core.sim import SimConfig, run_sim, slowdown_percentiles
from repro.core.workloads import make_messages


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="W3")
    ap.add_argument("--load", type=float, default=0.8)
    ap.add_argument("--messages", type=int, default=1500)
    a = ap.parse_args()

    tbl = make_messages(a.workload, n_hosts=8, load=a.load,
                        n_messages=a.messages, slot_bytes=256, seed=1)
    print(f"workload {a.workload} @ {a.load:.0%} load, "
          f"{a.messages} messages, 8 hosts")
    results = {}
    for proto in ("homa", "basic"):
        cfg = SimConfig(n_hosts=8, protocol=proto, max_slots=60_000,
                        ring_cap=2048)
        st = run_sim(cfg, tbl)
        results[proto] = st
        b = slowdown_percentiles(st, 99, n_buckets=8)
        print(f"\n{proto}: {st['n_complete']}/{st['n_messages']} complete, "
              f"priorities: {st['alloc'].n_unsched} unsched / "
              f"{st['alloc'].n_sched} sched, cutoffs {st['alloc'].cutoffs}")
        print("  size_bytes   p99_slowdown   median")
        for sz, p, m in zip(b["sizes"], b["p"], b["median"]):
            bar = "#" * min(int(p * 2), 60)
            print(f"  {int(sz):>9}   {p:>7.2f} {bar}")

    h = results["homa"]; bsc = results["basic"]
    ok_h = h["done"] & (h["size_bytes"] < 1000)
    ok_b = bsc["done"] & (bsc["size_bytes"] < 1000)
    ph = np.percentile(h["slowdown"][ok_h], 99)
    pb = np.percentile(bsc["slowdown"][ok_b], 99)
    print(f"\nsmall-message p99: homa {ph:.2f} vs basic {pb:.2f} "
          f"({pb / ph:.1f}x better)")


if __name__ == "__main__":
    main()
