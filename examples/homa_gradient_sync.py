"""Homa-scheduled data-parallel training on 8 (virtual) devices: chunked,
SRPT-ordered, overcommitment-bounded gradient collectives, with optional
int8 compression + error feedback.

    PYTHONPATH=src python examples/homa_gradient_sync.py [--compress]
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.configs.reduced import reduced_config
from repro.models import model as M
from repro.models.params import init_params
from repro.training.optimizer import OptConfig, init_opt_state, adamw_update
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.distrib import homa_collectives as HC


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--steps", type=int, default=30)
    a = ap.parse_args()

    mesh = jax.make_mesh((8,), ("data",))
    cfg = reduced_config("llama3.2-3b")
    oc = OptConfig(lr=1e-3, warmup_steps=5, total_steps=a.steps,
                   weight_decay=0.01)
    params = init_params(M.model_defs(cfg), jax.random.key(0))
    opt_state = init_opt_state(params, oc)

    scfg = HC.SyncConfig(chunk_bytes=1 << 14, overcommit=7,
                         compress="int8" if a.compress else None)
    err = HC.init_err_state(params, scfg)

    step = HC.build_dp_train_step(
        lambda p, b: M.loss_fn(cfg, p, b)[0],
        lambda p, g, s: adamw_update(p, g, s, oc),
        mesh, scfg)

    dc = DataConfig(seq_len=64, global_batch=16, vocab_size=cfg.vocab_size)
    src = SyntheticLM(dc)
    first = last = None
    for i in range(a.steps):
        batch = {k: jnp.asarray(v) for k, v in src.batch(i).items()}
        params, opt_state, metrics, err = step(params, opt_state, batch, err)
        loss = float(metrics["loss"])
        first = first if first is not None else loss
        last = loss
        if i % 5 == 0:
            print(f"step {i} loss {loss:.4f}")
    assert last < first, (first, last)
    print(f"homa_gradient_sync OK ({'int8' if a.compress else 'f32'}): "
          f"loss {first:.3f} -> {last:.3f} on {jax.device_count()} devices")


if __name__ == "__main__":
    main()
