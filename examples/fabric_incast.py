"""Leaf-spine fabric demo: an oversubscribed incast, homa vs basic.

Builds the paper's Fig. 14 shape — repeated fan-in bursts into one
receiver, Poisson background underneath — on a 16-host / 4-rack fabric
with 2:1 TOR-uplink oversubscription, and prints how each protocol's
small-message tail and per-tier queues hold up.

    PYTHONPATH=src python examples/fabric_incast.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import SimConfig, FabricConfig, simulate, scenarios  # noqa: E402


def main():
    tbl = scenarios.incast(12, 2048, n_hosts=16, n_bursts=8,
                           period_slots=1500, background="W2",
                           background_load=0.5, n_background=600, seed=2)
    fab = FabricConfig(racks=4, oversub=2.0, up_cap=1024)
    print(f"topology: {fab.racks} racks x {fab.rack_size(16)} hosts, "
          f"{fab.n_uplinks(16)} uplinks/TOR (oversub {fab.oversub}:1)")
    print(f"traffic: {len(tbl.size)} messages "
          f"(12-way incast bursts of 2 KB + W2 background)\n")

    for proto in ("homa", "basic"):
        cfg = SimConfig(protocol=proto, n_hosts=16, max_slots=16_000,
                        ring_cap=1024, fabric=fab)
        r = simulate(cfg, tbl)
        s = r.summary()
        f = s["fabric"]
        print(f"{proto:6s} p99 small {s['p99_small']:6.2f}   "
              f"complete {r.n_complete}/{r.n_messages}   "
              f"downlink qmax {s['q_max_bytes'] / 1024:6.1f} KB   "
              f"uplink qmax {f['up_q_max_bytes'] / 1024:6.1f} KB   "
              f"lost {r.lost_chunks}")
    print("\nHoma's wire priorities shield small messages at BOTH queueing"
          "\ntiers; basic funnels everything through one FIFO level.")


if __name__ == "__main__":
    main()
