"""Serving demo: the Homa-SRPT scheduler (repro.serving) driving real
batched decode of a Mamba2 model (SSM state caches are position-free, so
ragged continuous batching needs no padding tricks).

    PYTHONPATH=src python examples/serve_demo.py
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.reduced import reduced_config
from repro.models import model as M
from repro.models.params import init_params
from repro.serving.scheduler import HomaScheduler, SchedulerConfig, Request


def main():
    cfg = reduced_config("mamba2-130m")
    params = init_params(M.model_defs(cfg), jax.random.key(0))
    C = 4                                     # decode slots
    sched = HomaScheduler(SchedulerConfig(batch_size=C, overcommit=3,
                                          unsched_limit=4))

    # per-slot SSM caches (batch dim = C)
    shapes = M.cache_shapes(cfg, C, 1)
    caches = jax.tree.map(lambda s: jnp.zeros(s, jnp.bfloat16), shapes,
                          is_leaf=lambda x: isinstance(x, tuple))
    tokens = jnp.zeros((C, 1), jnp.int32)

    decode = jax.jit(lambda p, c, t: M.forward_decode(cfg, p, t, 1, c))

    rng = np.random.default_rng(0)
    for i in range(24):
        sched.submit(Request(rid=i, prompt_len=4,
                             max_new_tokens=int(rng.integers(2, 24)),
                             arrival=0.0))

    slot_of: dict[int, int] = {}
    state = {"caches": caches, "tokens": tokens}

    def decode_fn(batch):
        # place requests into slots (Homa "active" -> decode slot binding)
        free = [s for s in range(C)
                if s not in slot_of.values()]
        for r in batch:
            if r.rid not in slot_of:
                slot_of[r.rid] = free.pop(0)
        logits, deltas = decode(params, state["caches"], state["tokens"])
        # merge SSM cache deltas back per served slot
        def merge(old, new):
            return new.astype(old.dtype)
        state["caches"] = jax.tree.map(merge, state["caches"], deltas)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        state["tokens"] = nxt[:, None]
        done = []
        for r in batch:
            d = r.remaining <= 1
            if d:
                slot_of.pop(r.rid, None)
            done.append(d)
        return done

    t, steps = 0.0, 0
    while (sched.active or sched.queue) and steps < 2000:
        sched.step(decode_fn, t)
        t += 1.0
        steps += 1

    sl = sched.slowdowns()
    print(f"served {len(sched.finished)}/24 requests in {steps} steps")
    print(f"slowdown: mean {sl.mean():.2f}  p99 {np.percentile(sl, 99):.2f}")
    assert len(sched.finished) == 24
    print("serve_demo OK")


if __name__ == "__main__":
    main()
