"""Quickstart: end-to-end training with the public API — config, data
pipeline, AdamW, checkpointing, restart.

CPU-friendly default (reduced mamba2 config, 120 steps, ~2 min):

    PYTHONPATH=src python examples/quickstart.py

The real ~130M-parameter run (same driver, full config — sized for
accelerators):

    PYTHONPATH=src python examples/quickstart.py --full --steps 300
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


def sim_quickstart():
    """30-second tour of the transport-policy API: one structured run,
    then a 4-seed sweep batched behind a single jit trace."""
    from repro.core import (SimConfig, SweepSpec, simulate, run_sweep,
                            registered_protocols, make_messages)

    print(f"registered protocols: {', '.join(registered_protocols())}")
    tbl = make_messages("W1", n_hosts=4, load=0.7, n_messages=200,
                        slot_bytes=256, seed=0)
    cfg = SimConfig(protocol="homa", n_hosts=4, max_slots=2000, ring_cap=256)
    res = simulate(cfg, tbl)                       # -> SimResult
    print(f"homa: {res.n_complete}/{res.n_messages} complete, "
          f"p99 slowdown {res.percentile(99):.2f}, "
          f"downlink busy {float(res.busy_frac.mean()):.2%}")

    sweep = run_sweep(cfg, SweepSpec(seeds=(0, 1, 2, 3), workload="W1",
                                     load=0.7, n_messages=200,
                                     shared_alloc=True))
    p99s = [r.percentile(99) for r in sweep]
    print(f"4-seed sweep (one jit trace): p99 = "
          f"{', '.join(f'{p:.2f}' for p in p99s)}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_quickstart")
    a = ap.parse_args()

    sim_quickstart()
    from repro.launch import train   # deferred: needs the training deps

    argv = ["--arch", "mamba2-130m", "--steps", str(a.steps),
            "--seq-len", "128" if not a.full else "1024",
            "--batch", "8", "--lr", "3e-3",
            "--ckpt-dir", a.ckpt_dir, "--ckpt-every", "50",
            "--log-every", "10"]
    if not a.full:
        argv.append("--smoke")
    res = train.main(argv)
    assert res["final_loss"] < res["first_loss"], "loss did not improve"
    print(f"quickstart OK: loss {res['first_loss']:.3f} -> "
          f"{res['final_loss']:.3f} over {res['steps']} steps")


if __name__ == "__main__":
    main()
