"""Quickstart: end-to-end training with the public API — config, data
pipeline, AdamW, checkpointing, restart.

CPU-friendly default (reduced mamba2 config, 120 steps, ~2 min):

    PYTHONPATH=src python examples/quickstart.py

The real ~130M-parameter run (same driver, full config — sized for
accelerators):

    PYTHONPATH=src python examples/quickstart.py --full --steps 300
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_quickstart")
    a = ap.parse_args()

    argv = ["--arch", "mamba2-130m", "--steps", str(a.steps),
            "--seq-len", "128" if not a.full else "1024",
            "--batch", "8", "--lr", "3e-3",
            "--ckpt-dir", a.ckpt_dir, "--ckpt-every", "50",
            "--log-every", "10"]
    if not a.full:
        argv.append("--smoke")
    res = train.main(argv)
    assert res["final_loss"] < res["first_loss"], "loss did not improve"
    print(f"quickstart OK: loss {res['first_loss']:.3f} -> "
          f"{res['final_loss']:.3f} over {res['steps']} steps")


if __name__ == "__main__":
    main()
