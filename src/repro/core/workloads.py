"""Workloads W1-W5 (paper Fig. 1), re-synthesized.

The paper provides W1-W5 only as CDF plots; we reconstruct them as
log-uniform mixtures matched to the described statistics (W1: >70% of bytes
in <1000 B messages; W5: DCTCP web-search, 95% of bytes in >1 MB messages;
ordering by mean size W1 < ... < W5). Absolute numbers therefore track the
paper in shape/ordering rather than digit-for-digit — see DESIGN.md §2.1.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# (probability, lo_bytes, hi_bytes) bins; sizes log-uniform within a bin
WORKLOAD_BINS: dict[str, list[tuple[float, int, int]]] = {
    "W1": [(0.55, 10, 100), (0.40, 100, 1_000), (0.048, 1_000, 10_000),
           (0.002, 10_000, 30_000)],
    "W2": [(0.30, 3, 100), (0.40, 100, 2_000), (0.20, 2_000, 10_000),
           (0.08, 10_000, 100_000), (0.02, 100_000, 1_000_000)],
    "W3": [(0.25, 10, 300), (0.35, 300, 2_000), (0.25, 2_000, 20_000),
           (0.12, 20_000, 200_000), (0.03, 200_000, 2_000_000)],
    "W4": [(0.10, 30, 300), (0.25, 300, 3_000), (0.30, 3_000, 30_000),
           (0.25, 30_000, 300_000), (0.10, 300_000, 3_000_000)],
    "W5": [(0.40, 1_000, 10_000), (0.30, 10_000, 100_000),
           (0.20, 100_000, 1_000_000), (0.10, 1_000_000, 30_000_000)],
}


def sample_sizes(workload: str, n: int, rng: np.random.Generator,
                 max_bytes: int | None = None) -> np.ndarray:
    try:
        bins = WORKLOAD_BINS[workload]
    except KeyError:
        raise ValueError(
            f"unknown workload {workload!r}; available workloads: "
            f"{sorted(WORKLOAD_BINS)}") from None
    ps = np.array([b[0] for b in bins])
    ps = ps / ps.sum()
    which = rng.choice(len(bins), size=n, p=ps)
    lo = np.array([b[1] for b in bins])[which].astype(np.float64)
    hi = np.array([b[2] for b in bins])[which].astype(np.float64)
    u = rng.random(n)
    sizes = np.exp(np.log(lo) + u * (np.log(hi) - np.log(lo)))
    sizes = np.maximum(sizes.astype(np.int64), 1)
    if max_bytes:
        sizes = np.minimum(sizes, max_bytes)
    return sizes


@dataclasses.dataclass
class MessageTable:
    """Open-loop Poisson message arrivals for the simulator."""
    src: np.ndarray          # (M,) int32
    dst: np.ndarray          # (M,) int32
    size: np.ndarray         # (M,) int64 bytes
    arrival_slot: np.ndarray  # (M,) int32
    workload: str
    load: float
    slot_bytes: int


_SPEC_KINDS = ("poisson", "incast", "hotspot", "shuffle")

# fields each kind requires beyond the defaults
_SPEC_REQUIRED = {
    "poisson": ("workload", "load"),
    "incast": ("fan_in", "burst_bytes"),
    "hotspot": ("workload", "load"),
    "shuffle": ("bytes_per_pair",),
}


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """One frozen description of how to generate a :class:`MessageTable`.

    Unifies :func:`make_messages` and the scenario generators
    (``scenarios.incast`` / ``hotspot`` / ``shuffle``) behind a single
    spec type that :class:`repro.core.sweep.SweepSpec` and
    ``benchmarks/common.sim_sweep`` accept directly — those functions
    remain as thin wrappers over ``WorkloadSpec(...).build(...)``, so
    generation (and its RNG draw order) is defined in exactly one place.

    Only the fields of the chosen ``kind`` matter; topology-dependent
    parameters (``n_hosts``, ``slot_bytes``) stay out of the spec and go
    to :meth:`build`, so one spec serves every topology in a sweep.
    """
    kind: str = "poisson"            # poisson | incast | hotspot | shuffle
    # poisson / hotspot base workload
    workload: str | None = None      # W1..W5
    load: float | None = None
    n_messages: int = 2000
    seed: int = 0
    max_bytes: int | None = None
    incast: tuple[int, int, int] | None = None   # poisson burst overlay
    # incast scenario
    fan_in: int | None = None
    burst_bytes: int | None = None
    dst: int = 0
    n_bursts: int = 1
    period_slots: int = 2000
    first_slot: int = 0
    background: str | None = None
    background_load: float = 0.0
    n_background: int = 0
    # hotspot
    hot_fraction: float = 0.5
    n_hot: int = 1
    # shuffle
    bytes_per_pair: int | None = None
    spread_slots: int = 0

    def __post_init__(self):
        if self.kind not in _SPEC_KINDS:
            raise ValueError(f"unknown WorkloadSpec kind {self.kind!r}; "
                             f"one of {_SPEC_KINDS}")
        missing = [f for f in _SPEC_REQUIRED[self.kind]
                   if getattr(self, f) is None]
        if missing:
            raise ValueError(f"WorkloadSpec(kind={self.kind!r}) requires "
                             f"{missing}")
        if self.incast is not None:
            object.__setattr__(self, "incast", tuple(self.incast))

    def with_seed(self, seed: int) -> "WorkloadSpec":
        return dataclasses.replace(self, seed=seed)

    def build(self, *, n_hosts: int, slot_bytes: int = 256) -> MessageTable:
        """Generate the table for a concrete topology."""
        if self.kind == "poisson":
            return _poisson_table(self, n_hosts, slot_bytes)
        # scenario kinds: generation lives in repro.core.scenarios
        # (deferred import — scenarios builds on this module)
        from repro.core import scenarios
        impl = {"incast": scenarios._incast_impl,
                "hotspot": scenarios._hotspot_impl,
                "shuffle": scenarios._shuffle_impl}[self.kind]
        return impl(self, n_hosts, slot_bytes)


def _poisson_table(ws: WorkloadSpec, n_hosts: int,
                   slot_bytes: int) -> MessageTable:
    rng = np.random.default_rng(ws.seed)
    sizes = sample_sizes(ws.workload, ws.n_messages, rng, ws.max_bytes)
    # slots consumed per message on a link (ceil -> includes packetization)
    slots = np.maximum((sizes + slot_bytes - 1) // slot_bytes, 1)
    # aggregate service capacity: n_hosts slots per tick
    mean_gap = slots.mean() / (ws.load * n_hosts)
    gaps = rng.exponential(mean_gap, ws.n_messages)
    arrivals = np.floor(np.cumsum(gaps)).astype(np.int64)
    src = rng.integers(0, n_hosts, ws.n_messages)
    dst = rng.integers(0, n_hosts - 1, ws.n_messages)
    dst = np.where(dst >= src, dst + 1, dst)   # dst != src
    tbl = MessageTable(src.astype(np.int32), dst.astype(np.int32),
                       sizes, arrivals.astype(np.int32), ws.workload,
                       ws.load, slot_bytes)
    if ws.incast is not None:
        from repro.core import scenarios
        fan_in, burst_bytes, period_slots = ws.incast
        if period_slots < 1:
            raise ValueError(f"incast period_slots must be >= 1, got "
                             f"{period_slots}")
        horizon = int(arrivals.max()) if ws.n_messages else 0
        bursts = scenarios.incast(
            fan_in, burst_bytes, n_hosts=n_hosts, slot_bytes=slot_bytes,
            n_bursts=max(horizon // period_slots, 1),
            period_slots=period_slots, first_slot=period_slots,
            seed=ws.seed)
        tbl = scenarios.merge_tables(tbl, bursts, workload=ws.workload,
                                     load=ws.load)
    return tbl


def make_messages(workload: str, *, n_hosts: int, load: float,
                  n_messages: int, slot_bytes: int, seed: int = 0,
                  max_bytes: int | None = None,
                  incast: tuple[int, int, int] | None = None) -> MessageTable:
    """Poisson arrivals at aggregate rate = load * n_hosts * link rate.

    Each host's downlink drains one slot (slot_bytes) per tick; `load` is the
    fraction of aggregate link bandwidth consumed by message bytes.

    ``incast=(fan_in, burst_bytes, period_slots)`` overlays periodic
    fan-in bursts on the background traffic: every ``period_slots``,
    ``fan_in`` senders each emit one ``burst_bytes`` response to host 0
    simultaneously (``repro.core.scenarios.incast``), until the
    background's arrival horizon is covered.

    Thin wrapper over ``WorkloadSpec(kind="poisson", ...).build(...)``.
    """
    return WorkloadSpec(kind="poisson", workload=workload, load=load,
                        n_messages=n_messages, seed=seed,
                        max_bytes=max_bytes, incast=incast).build(
                            n_hosts=n_hosts, slot_bytes=slot_bytes)


def bytes_weighted_unsched_fraction(sizes: np.ndarray, unsched_limit: int) -> float:
    return float(np.minimum(sizes, unsched_limit).sum() / sizes.sum())
