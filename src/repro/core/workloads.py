"""Workloads W1-W5 (paper Fig. 1), re-synthesized.

The paper provides W1-W5 only as CDF plots; we reconstruct them as
log-uniform mixtures matched to the described statistics (W1: >70% of bytes
in <1000 B messages; W5: DCTCP web-search, 95% of bytes in >1 MB messages;
ordering by mean size W1 < ... < W5). Absolute numbers therefore track the
paper in shape/ordering rather than digit-for-digit — see DESIGN.md §2.1.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# (probability, lo_bytes, hi_bytes) bins; sizes log-uniform within a bin
WORKLOAD_BINS: dict[str, list[tuple[float, int, int]]] = {
    "W1": [(0.55, 10, 100), (0.40, 100, 1_000), (0.048, 1_000, 10_000),
           (0.002, 10_000, 30_000)],
    "W2": [(0.30, 3, 100), (0.40, 100, 2_000), (0.20, 2_000, 10_000),
           (0.08, 10_000, 100_000), (0.02, 100_000, 1_000_000)],
    "W3": [(0.25, 10, 300), (0.35, 300, 2_000), (0.25, 2_000, 20_000),
           (0.12, 20_000, 200_000), (0.03, 200_000, 2_000_000)],
    "W4": [(0.10, 30, 300), (0.25, 300, 3_000), (0.30, 3_000, 30_000),
           (0.25, 30_000, 300_000), (0.10, 300_000, 3_000_000)],
    "W5": [(0.40, 1_000, 10_000), (0.30, 10_000, 100_000),
           (0.20, 100_000, 1_000_000), (0.10, 1_000_000, 30_000_000)],
}


def sample_sizes(workload: str, n: int, rng: np.random.Generator,
                 max_bytes: int | None = None) -> np.ndarray:
    try:
        bins = WORKLOAD_BINS[workload]
    except KeyError:
        raise ValueError(
            f"unknown workload {workload!r}; available workloads: "
            f"{sorted(WORKLOAD_BINS)}") from None
    ps = np.array([b[0] for b in bins])
    ps = ps / ps.sum()
    which = rng.choice(len(bins), size=n, p=ps)
    lo = np.array([b[1] for b in bins])[which].astype(np.float64)
    hi = np.array([b[2] for b in bins])[which].astype(np.float64)
    u = rng.random(n)
    sizes = np.exp(np.log(lo) + u * (np.log(hi) - np.log(lo)))
    sizes = np.maximum(sizes.astype(np.int64), 1)
    if max_bytes:
        sizes = np.minimum(sizes, max_bytes)
    return sizes


@dataclasses.dataclass
class MessageTable:
    """Open-loop Poisson message arrivals for the simulator."""
    src: np.ndarray          # (M,) int32
    dst: np.ndarray          # (M,) int32
    size: np.ndarray         # (M,) int64 bytes
    arrival_slot: np.ndarray  # (M,) int32
    workload: str
    load: float
    slot_bytes: int


def make_messages(workload: str, *, n_hosts: int, load: float,
                  n_messages: int, slot_bytes: int, seed: int = 0,
                  max_bytes: int | None = None,
                  incast: tuple[int, int, int] | None = None) -> MessageTable:
    """Poisson arrivals at aggregate rate = load * n_hosts * link rate.

    Each host's downlink drains one slot (slot_bytes) per tick; `load` is the
    fraction of aggregate link bandwidth consumed by message bytes.

    ``incast=(fan_in, burst_bytes, period_slots)`` overlays periodic
    fan-in bursts on the background traffic: every ``period_slots``,
    ``fan_in`` senders each emit one ``burst_bytes`` response to host 0
    simultaneously (``repro.core.scenarios.incast``), until the
    background's arrival horizon is covered.
    """
    rng = np.random.default_rng(seed)
    sizes = sample_sizes(workload, n_messages, rng, max_bytes)
    # slots consumed per message on a link (ceil -> includes packetization)
    slots = np.maximum((sizes + slot_bytes - 1) // slot_bytes, 1)
    # aggregate service capacity: n_hosts slots per tick
    mean_gap = slots.mean() / (load * n_hosts)
    gaps = rng.exponential(mean_gap, n_messages)
    arrivals = np.floor(np.cumsum(gaps)).astype(np.int64)
    src = rng.integers(0, n_hosts, n_messages)
    dst = rng.integers(0, n_hosts - 1, n_messages)
    dst = np.where(dst >= src, dst + 1, dst)   # dst != src
    tbl = MessageTable(src.astype(np.int32), dst.astype(np.int32),
                       sizes, arrivals.astype(np.int32), workload, load,
                       slot_bytes)
    if incast is not None:
        # deferred import: scenarios builds on this module's generators
        from repro.core import scenarios
        fan_in, burst_bytes, period_slots = incast
        if period_slots < 1:
            raise ValueError(f"incast period_slots must be >= 1, got "
                             f"{period_slots}")
        horizon = int(arrivals.max()) if n_messages else 0
        bursts = scenarios.incast(
            fan_in, burst_bytes, n_hosts=n_hosts, slot_bytes=slot_bytes,
            n_bursts=max(horizon // period_slots, 1),
            period_slots=period_slots, first_slot=period_slots, seed=seed)
        tbl = scenarios.merge_tables(tbl, bursts, workload=workload,
                                     load=load)
    return tbl


def bytes_weighted_unsched_fraction(sizes: np.ndarray, unsched_limit: int) -> float:
    return float(np.minimum(sizes, unsched_limit).sum() / sizes.sum())
