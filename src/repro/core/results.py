"""Structured simulation results.

The simulator historically returned a raw dict; :class:`SimResult` makes
the quantities every consumer recomputed by hand — slowdown percentiles,
utilization, queue stats, priority usage — first-class fields and methods,
with :meth:`SimResult.to_json` providing the JSON-safe summary the
benchmark cache stores.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Any

import numpy as np


def _json_safe(v):
    """Recursively convert numpy scalars/arrays, tuples, and non-finite
    floats (NaN -> null) into strict-JSON-serializable values."""
    if isinstance(v, np.ndarray):
        return _json_safe(v.tolist())
    if isinstance(v, np.integer):
        return int(v)
    if isinstance(v, np.floating):
        v = float(v)
    if isinstance(v, float):
        return v if math.isfinite(v) else None
    if isinstance(v, np.bool_):
        return bool(v)
    if isinstance(v, dict):
        return {k: _json_safe(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_json_safe(x) for x in v]
    return v


def bucketed_percentiles(size_bytes: np.ndarray, slowdown: np.ndarray,
                         done: np.ndarray, pct: float = 99.0,
                         n_buckets: int = 10) -> dict:
    """Percentile slowdown bucketed by message size (paper Figs. 8/12)."""
    ok = done & np.isfinite(slowdown)
    sizes = size_bytes[ok]
    sl = slowdown[ok]
    if len(sizes) == 0:
        # same schema as the populated case (count included) whether the
        # input was empty or merely had no finished messages
        return {"sizes": [], "p": [], "median": [], "count": []}
    order = np.argsort(sizes)
    sizes, sl = sizes[order], sl[order]
    edges = np.linspace(0, len(sizes), n_buckets + 1).astype(int)
    out = {"sizes": [], "p": [], "median": [], "count": []}
    for i in range(n_buckets):
        lo, hi = edges[i], edges[i + 1]
        if hi <= lo:
            continue
        out["sizes"].append(float(np.median(sizes[lo:hi])))
        out["p"].append(float(np.percentile(sl[lo:hi], pct)))
        out["median"].append(float(np.percentile(sl[lo:hi], 50)))
        out["count"].append(int(hi - lo))
    return out


@dataclasses.dataclass
class SimResult:
    """One simulation run, post-processed to numpy.

    Per-message arrays are aligned with the input ``MessageTable``;
    per-host arrays have shape ``(n_hosts,)``.
    """
    protocol: str
    alloc: Any                       # PriorityAllocation
    # per-message
    completion: np.ndarray           # slot of completion, -1 if unfinished
    elapsed: np.ndarray              # completion - arrival + 1, -1 if unfin.
    ideal: np.ndarray                # unloaded transmission time (slots)
    slowdown: np.ndarray             # elapsed / ideal, NaN if unfinished
    done: np.ndarray                 # bool
    size_slots: np.ndarray
    size_bytes: np.ndarray
    # per-host utilization
    busy_frac: np.ndarray            # downlink busy fraction
    wasted_frac: np.ndarray          # idle-but-withheld fraction (Fig. 16)
    uplink_busy_frac: np.ndarray
    # queue + priority stats
    q_mean_bytes: np.ndarray
    q_max_bytes: np.ndarray
    prio_drained_bytes: np.ndarray   # (n_prios,) bytes drained per level
    # scalars
    lost_chunks: int                 # all tiers (downlink + TOR uplink)
    n_complete: int
    n_messages: int
    # leaf-spine fabric tier (None / zero when the run was single-switch)
    fabric: dict | None = None       # topology: racks/rack_size/n_uplinks/...
    tor_up_busy_frac: np.ndarray | None = None    # (U,) uplink utilization
    tor_up_q_mean_bytes: np.ndarray | None = None
    tor_up_q_max_bytes: np.ndarray | None = None
    tor_up_lost_chunks: int = 0
    # fault-injection layer (None / zero when faults were disabled):
    faults: dict | None = None       # FaultConfig echo (loss rates, windows)
    retx_chunks: np.ndarray | None = None      # (M,) rewound-chunk credits
    msg_lost_chunks: np.ndarray | None = None  # (M,) fault-dropped chunks
    recovery_slots: np.ndarray | None = None   # (M,) first loss -> done; -1
    fault_lost_chunks: int = 0       # total chunks dropped by fault injection
    # host/NIC software-overhead stage (None when SimConfig.host was off
    # or ideal — repro.core.hostmodel, DESIGN.md §10); per-host (H,)
    host: dict | None = None         # HostConfig echo (model, costs, caps)
    host_tx_busy_frac: np.ndarray | None = None   # TX CPU time / horizon
    host_tx_defer_frac: np.ndarray | None = None  # slots gated w/ traffic
    host_rx_stall_frac: np.ndarray | None = None  # slots downlink stalled
    host_rx_q_mean_chunks: np.ndarray | None = None  # RX ring backlog
    host_rx_q_max_chunks: np.ndarray | None = None
    # telemetry capture (None when SimConfig.trace was off, DESIGN.md §8):
    # trace is the full SimTrace (simulate only — run_sweep keeps just
    # trace_summary, the reduced streaming-stat dict)
    trace: Any | None = None         # repro.core.telemetry.SimTrace
    trace_summary: dict | None = None
    # optional raw scan state (return_state=True)
    state: dict | None = None
    static: dict | None = None

    # ------------------------------------------------------------ derived

    @property
    def completion_rate(self) -> float:
        return float(self.done.mean()) if self.n_messages else 0.0

    def steady_mask(self, warmup_frac: float = 0.1) -> np.ndarray:
        """Completion mask with the first ``warmup_frac`` of arrivals
        dropped (steady-state window)."""
        ok = self.done.copy()
        ok[:int(self.n_messages * warmup_frac)] = False
        return ok

    def percentile(self, q: float, mask: np.ndarray | None = None
                   ) -> float | None:
        """Slowdown percentile over ``mask`` (default: completed msgs)."""
        m = self.done if mask is None else mask
        m = m & np.isfinite(self.slowdown)
        if m.sum() == 0:
            return None
        return float(np.percentile(self.slowdown[m], q))

    def percentiles_by_size(self, pct: float = 99.0, n_buckets: int = 10,
                            mask: np.ndarray | None = None) -> dict:
        return bucketed_percentiles(
            self.size_bytes, self.slowdown,
            self.done if mask is None else mask, pct, n_buckets)

    # ------------------------------------------------------- serialization

    def summary(self, *, warmup_frac: float = 0.1, small_bytes: int = 1000,
                pct: float = 99.0) -> dict:
        """JSON-safe aggregate summary (the benchmark-cache schema)."""
        ok = self.steady_mask(warmup_frac)
        small = ok & (self.size_bytes < small_bytes)
        fabric = None
        if self.fabric is not None:
            fabric = {
                **self.fabric,
                "up_busy_frac": float(np.mean(self.tor_up_busy_frac)),
                "up_q_mean_bytes": float(np.mean(self.tor_up_q_mean_bytes)),
                "up_q_max_bytes": float(np.max(self.tor_up_q_max_bytes)),
                "up_lost_chunks": int(self.tor_up_lost_chunks),
            }
        faults = None
        if self.faults is not None:
            rec = self.recovery_slots
            hit = rec >= 0          # fault-affected messages that finished
            faults = {
                **{k: list(v) if isinstance(v, tuple) else v
                   for k, v in self.faults.items()},
                "fault_lost_chunks": int(self.fault_lost_chunks),
                "retx_chunks": int(np.sum(self.retx_chunks)),
                "msgs_lossy": int(np.sum(self.msg_lost_chunks > 0)),
                "recovery_mean_slots": float(np.mean(rec[hit]))
                if hit.any() else None,
                "recovery_p99_slots": float(np.percentile(rec[hit], 99))
                if hit.any() else None,
            }
        host = None
        if self.host is not None:
            host = dict(self.host)
            if self.host_tx_busy_frac is not None:
                host["tx_busy_frac"] = float(np.mean(self.host_tx_busy_frac))
                host["tx_defer_frac"] = float(
                    np.mean(self.host_tx_defer_frac))
            if self.host_rx_stall_frac is not None:
                host["rx_stall_frac"] = float(
                    np.mean(self.host_rx_stall_frac))
                host["rx_q_mean_chunks"] = float(
                    np.mean(self.host_rx_q_mean_chunks))
                host["rx_q_max_chunks"] = int(
                    np.max(self.host_rx_q_max_chunks))
        return {
            "protocol": self.protocol,
            "n_complete": int(self.n_complete),
            "n_messages": int(self.n_messages),
            "completion_rate": self.completion_rate,
            "p99_by_size": self.percentiles_by_size(pct, mask=ok),
            "busy_frac": float(np.mean(self.busy_frac)),
            "wasted_frac": float(np.mean(self.wasted_frac)),
            "uplink_busy_frac": float(np.mean(self.uplink_busy_frac)),
            "q_mean_bytes": float(np.mean(self.q_mean_bytes)),
            "q_max_bytes": float(np.max(self.q_max_bytes)),
            "prio_drained_bytes": [int(x) for x in self.prio_drained_bytes],
            "lost_chunks": int(self.lost_chunks),
            "alloc": {"n_unsched": self.alloc.n_unsched,
                      "cutoffs": list(self.alloc.cutoffs),
                      "unsched_frac": self.alloc.unsched_bytes_frac},
            "p99_small": self.percentile(pct, small),
            "p50_small": self.percentile(50, small),
            "p99_all": self.percentile(pct, ok),
            "p50_all": self.percentile(50, ok),
            "fabric": fabric,
            "faults": faults,
            "host": host,
            "trace": self.trace_summary,
        }

    # every per-message / per-host array field, with the dtype family
    # from_json restores it as (dtype identity is not part of the
    # round-trip contract; values — including NaN — are)
    _ARRAY_FIELDS = {
        "completion": np.int64, "elapsed": np.int64, "ideal": np.int64,
        "slowdown": np.float64, "done": np.bool_,
        "size_slots": np.int64, "size_bytes": np.int64,
        "busy_frac": np.float64, "wasted_frac": np.float64,
        "uplink_busy_frac": np.float64,
        "q_mean_bytes": np.float64, "q_max_bytes": np.int64,
        "prio_drained_bytes": np.int64,
        "tor_up_busy_frac": np.float64, "tor_up_q_mean_bytes": np.float64,
        "tor_up_q_max_bytes": np.int64,
        "retx_chunks": np.int64, "msg_lost_chunks": np.int64,
        "recovery_slots": np.int64,
        "host_tx_busy_frac": np.float64, "host_tx_defer_frac": np.float64,
        "host_rx_stall_frac": np.float64,
        "host_rx_q_mean_chunks": np.float64,
        "host_rx_q_max_chunks": np.int64,
    }
    _SKIP_FIELDS = ("state", "static", "trace")   # not JSON-serialized

    def to_json(self, *, full: bool = False, **kwargs) -> str:
        """JSON string of the aggregate :meth:`summary` (default), or —
        with ``full=True`` — of every array field, round-trippable
        through :meth:`from_json` (the bench-cache full-result form).
        Both are strict JSON (numpy scalars unwrapped, NaN -> null)."""
        if not full:
            return json.dumps(_json_safe(self.summary(**kwargs)))
        d = {"__simresult__": 1}
        for f in dataclasses.fields(self):
            if f.name in self._SKIP_FIELDS:
                continue
            v = getattr(self, f.name)
            if f.name == "alloc" and v is not None:
                v = {"n_prios": v.n_prios, "n_unsched": v.n_unsched,
                     "cutoffs": list(v.cutoffs),
                     "unsched_bytes_frac": v.unsched_bytes_frac}
            d[f.name] = _json_safe(v)
        return json.dumps(d)

    @classmethod
    def from_json(cls, s: str | dict) -> "SimResult":
        """Rebuild a :class:`SimResult` from :meth:`to_json(full=True)
        <to_json>` output (str or already-parsed dict). Array fields come
        back as numpy (nulls in float arrays -> NaN); ``state`` /
        ``static`` / the full ``trace`` are not round-tripped."""
        d = dict(json.loads(s)) if isinstance(s, str) else dict(s)
        if not d.pop("__simresult__", None):
            raise ValueError("not a full SimResult serialization; use "
                             "to_json(full=True) to produce one")
        if isinstance(d.get("alloc"), dict):
            from repro.core.priorities import PriorityAllocation
            a = d["alloc"]
            d["alloc"] = PriorityAllocation(
                n_prios=a["n_prios"], n_unsched=a["n_unsched"],
                cutoffs=tuple(a["cutoffs"]),
                unsched_bytes_frac=a["unsched_bytes_frac"])
        for name, dt in cls._ARRAY_FIELDS.items():
            if d.get(name) is not None:
                d[name] = np.asarray(d[name], dtype=dt)
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})
