"""Structured traffic scenarios layered on :mod:`repro.core.workloads`.

Each generator returns an ordinary :class:`MessageTable`, so scenarios
run through ``simulate`` / ``run_sweep`` (and the cached benchmark
``sim_sweep`` path) with zero simulator changes. Three shapes from the
paper's evaluation plus the classic fabric stress patterns:

  ``incast``   fan-in burst: N servers answer one client at once
               (paper Fig. 14), optionally repeated and overlaid on
               Poisson background traffic so tail percentiles of small
               messages stay measurable.
  ``hotspot``  skewed destination popularity — a fraction of all
               messages targets a small hot set of hosts, concentrating
               load on their rack's downlinks and uplinks.
  ``shuffle``  all-to-all: every ordered host pair exchanges one
               fixed-size message (map-reduce shuffle), the canonical
               TOR-uplink oversubscription stressor.

All generators are deterministic in ``seed``.

Failure scenarios (DESIGN.md §7) live on the *fabric* axis instead: the
``lossy_fabric`` / ``uplink_failure`` / ``tor_failure`` helpers attach a
:class:`~repro.core.faults.FaultConfig` to an existing
:class:`~repro.core.fabric.FabricConfig`, so any traffic scenario above
composes with any failure scenario by pairing a table with a faulted
fabric.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.fabric import FabricConfig
from repro.core.faults import FaultConfig
from repro.core.workloads import MessageTable, make_messages


def merge_tables(a: MessageTable, b: MessageTable, *, workload: str,
                 load: float) -> MessageTable:
    """Concatenate two tables and re-sort by arrival (stable, so same-slot
    ordering keeps background before burst within a slot). Public: the
    overlay primitive scenario generators and ``make_messages``' incast
    wiring both build on."""
    if a.slot_bytes != b.slot_bytes:
        raise ValueError(
            f"cannot merge tables with different slot sizes "
            f"({a.slot_bytes} vs {b.slot_bytes} bytes): the simulator "
            f"packetizes every message at one slot granularity")
    src = np.concatenate([a.src, b.src])
    dst = np.concatenate([a.dst, b.dst])
    size = np.concatenate([a.size, b.size])
    arr = np.concatenate([a.arrival_slot, b.arrival_slot])
    order = np.argsort(arr, kind="stable")
    return MessageTable(src[order].astype(np.int32),
                        dst[order].astype(np.int32),
                        size[order].astype(np.int64),
                        arr[order].astype(np.int32),
                        workload, load, a.slot_bytes)


def incast(fan_in: int, burst_bytes: int, *, n_hosts: int,
           slot_bytes: int = 256, dst: int = 0, n_bursts: int = 1,
           period_slots: int = 2000, first_slot: int = 0,
           background: str | None = None, background_load: float = 0.0,
           n_background: int = 0, seed: int = 0) -> MessageTable:
    """Fan-in burst scenario (paper Fig. 14 shape).

    Every ``period_slots`` (starting at ``first_slot``), ``fan_in``
    distinct senders each emit one ``burst_bytes`` response to ``dst``
    simultaneously — the application issued a request to ``fan_in``
    servers and all replies collide at one downlink. Senders are chosen
    round-robin over the other hosts so bursts span racks under any
    rack partition. With ``background``/``background_load``/
    ``n_background`` set, a Poisson workload table is overlaid.
    """
    if not 1 <= fan_in <= n_hosts - 1:
        raise ValueError(f"incast fan_in must be in [1, n_hosts-1], got "
                         f"{fan_in} with n_hosts={n_hosts}")
    others = np.array([h for h in range(n_hosts) if h != dst], np.int32)
    rng = np.random.default_rng(seed)
    srcs, arrs = [], []
    for b in range(n_bursts):
        start = int(rng.integers(len(others)))      # rotate the sender set
        sel = others[(start + np.arange(fan_in)) % len(others)]
        srcs.append(sel)
        arrs.append(np.full(fan_in, first_slot + b * period_slots))
    src = np.concatenate(srcs).astype(np.int32)
    arr = np.concatenate(arrs).astype(np.int32)
    tbl = MessageTable(src, np.full_like(src, dst),
                       np.full(len(src), burst_bytes, np.int64),
                       arr, f"incast{fan_in}x{burst_bytes}", 0.0,
                       slot_bytes)
    if n_background and background:
        bg = make_messages(background, n_hosts=n_hosts,
                           load=background_load, n_messages=n_background,
                           slot_bytes=slot_bytes, seed=seed + 1)
        tbl = merge_tables(bg, tbl, workload=f"incast+{background}",
                           load=background_load)
    return tbl


def hotspot(workload: str, *, n_hosts: int, load: float, n_messages: int,
            slot_bytes: int = 256, hot_fraction: float = 0.5,
            n_hot: int = 1, seed: int = 0) -> MessageTable:
    """Skewed destination popularity: ``hot_fraction`` of all messages
    are redirected to a hot set of ``n_hot`` hosts (the first ``n_hot``
    host ids), the rest keep their uniform destinations. Sizes and
    arrivals come from the base Poisson workload unchanged."""
    if not 0.0 <= hot_fraction <= 1.0:
        raise ValueError(f"hot_fraction must be in [0, 1], got "
                         f"{hot_fraction}")
    if not 1 <= n_hot < n_hosts:
        raise ValueError(f"n_hot must be in [1, n_hosts), got {n_hot}")
    tbl = make_messages(workload, n_hosts=n_hosts, load=load,
                        n_messages=n_messages, slot_bytes=slot_bytes,
                        seed=seed)
    rng = np.random.default_rng(seed + 0x5EED)
    redirect = rng.random(n_messages) < hot_fraction
    hot_dst = rng.integers(0, n_hot, n_messages).astype(np.int32)
    dst = np.where(redirect, hot_dst, tbl.dst).astype(np.int32)
    # a hot host never sends to itself: bounce to the next host id
    clash = dst == tbl.src
    dst[clash] = (dst[clash] + 1) % n_hosts
    return MessageTable(tbl.src, dst, tbl.size, tbl.arrival_slot,
                        f"hotspot:{workload}", load, slot_bytes)


def shuffle(*, n_hosts: int, bytes_per_pair: int, slot_bytes: int = 256,
            spread_slots: int = 0, seed: int = 0) -> MessageTable:
    """All-to-all shuffle: every ordered pair (i, j), i != j, exchanges
    one ``bytes_per_pair`` message. Arrivals are uniform over
    ``spread_slots`` (0 = everything starts at slot 0) in a seeded
    random pair order — the map-reduce shuffle that saturates
    oversubscribed TOR uplinks."""
    pairs = np.array([(i, j) for i in range(n_hosts)
                      for j in range(n_hosts) if i != j], np.int32)
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(pairs))
    pairs = pairs[order]
    if spread_slots > 0:
        arr = np.sort(rng.integers(0, spread_slots, len(pairs)))
    else:
        arr = np.zeros(len(pairs), np.int64)
    return MessageTable(pairs[:, 0], pairs[:, 1],
                        np.full(len(pairs), bytes_per_pair, np.int64),
                        arr.astype(np.int32), "shuffle", 1.0, slot_bytes)


# ------------------------------------------------- failure scenarios ------

def _with_faults(fab: FabricConfig, **fault_kw) -> FabricConfig:
    if not fab.enabled:
        raise ValueError("failure scenarios need an enabled fabric "
                         "(FabricConfig with racks set): faults model "
                         "loss on leaf-spine links")
    base = dataclasses.asdict(fab.faults) if fab.faults is not None else {}
    return dataclasses.replace(fab, faults=FaultConfig(**{**base,
                                                          **fault_kw}))


def lossy_fabric(fab: FabricConfig, *, up_loss: float = 0.0,
                 down_loss: float = 0.0, ge_p_gb: float = 0.0,
                 ge_p_bg: float = 0.05, ge_loss: float = 0.5,
                 seed: int = 0) -> FabricConfig:
    """Steady-state lossy links: Bernoulli uplink/downlink chunk loss,
    optionally with a Gilbert-Elliott burst component."""
    return _with_faults(fab, up_loss=up_loss, down_loss=down_loss,
                        ge_p_gb=ge_p_gb, ge_p_bg=ge_p_bg, ge_loss=ge_loss,
                        seed=seed)


def uplink_failure(fab: FabricConfig, *, uplink: int, start: int,
                   end: int) -> FabricConfig:
    """One TOR uplink black-holes all traffic for ``[start, end)`` slots
    — the scenario where routing policy dominates: static ECMP keeps
    hashing flows into the dead spine until the window lifts."""
    prior = fab.faults.link_fail if fab.faults is not None else ()
    return _with_faults(fab, link_fail=prior + ((uplink, start, end),))


def tor_failure(fab: FabricConfig, *, rack: int, start: int,
                end: int) -> FabricConfig:
    """A whole TOR fails for ``[start, end)`` slots: the rack's uplinks
    and host downlinks all go dark; recovery timeouts must carry every
    in-flight message across the window."""
    prior = fab.faults.tor_fail if fab.faults is not None else ()
    return _with_faults(fab, tor_fail=prior + ((rack, start, end),))


__all__ = ["incast", "hotspot", "shuffle", "merge_tables",
           "lossy_fabric", "uplink_failure", "tor_failure"]
