"""Structured traffic scenarios layered on :mod:`repro.core.workloads`.

Each generator returns an ordinary :class:`MessageTable`, so scenarios
run through ``simulate`` / ``run_sweep`` (and the cached benchmark
``sim_sweep`` path) with zero simulator changes. Three shapes from the
paper's evaluation plus the classic fabric stress patterns:

  ``incast``   fan-in burst: N servers answer one client at once
               (paper Fig. 14), optionally repeated and overlaid on
               Poisson background traffic so tail percentiles of small
               messages stay measurable.
  ``hotspot``  skewed destination popularity — a fraction of all
               messages targets a small hot set of hosts, concentrating
               load on their rack's downlinks and uplinks.
  ``shuffle``  all-to-all: every ordered host pair exchanges one
               fixed-size message (map-reduce shuffle), the canonical
               TOR-uplink oversubscription stressor.

All generators are deterministic in ``seed`` and are thin wrappers over
:class:`repro.core.workloads.WorkloadSpec` — the frozen spec type that
``SweepSpec`` and ``benchmarks/common.sim_sweep`` accept directly; the
``_*_impl`` functions here hold the actual generation and are dispatched
from ``WorkloadSpec.build``.

Failure scenarios (DESIGN.md §7) live on the *fabric* axis instead:
``FabricConfig.with_lossy`` / ``.with_uplink_failure`` /
``.with_tor_failure`` attach a :class:`~repro.core.faults.FaultConfig`
to an existing :class:`~repro.core.fabric.FabricConfig`, so any traffic
scenario above composes with any failure scenario by pairing a table
with a faulted fabric. The original ``lossy_fabric`` / ``uplink_failure``
/ ``tor_failure`` helpers are re-exported here for compatibility.
"""
from __future__ import annotations

import numpy as np

from repro.core.fabric import FabricConfig
from repro.core.workloads import MessageTable, WorkloadSpec, make_messages


def merge_tables(a: MessageTable, b: MessageTable, *, workload: str,
                 load: float) -> MessageTable:
    """Concatenate two tables and re-sort by arrival (stable, so same-slot
    ordering keeps background before burst within a slot). Public: the
    overlay primitive scenario generators and ``make_messages``' incast
    wiring both build on."""
    if a.slot_bytes != b.slot_bytes:
        raise ValueError(
            f"cannot merge tables with different slot sizes "
            f"({a.slot_bytes} vs {b.slot_bytes} bytes): the simulator "
            f"packetizes every message at one slot granularity")
    src = np.concatenate([a.src, b.src])
    dst = np.concatenate([a.dst, b.dst])
    size = np.concatenate([a.size, b.size])
    arr = np.concatenate([a.arrival_slot, b.arrival_slot])
    order = np.argsort(arr, kind="stable")
    return MessageTable(src[order].astype(np.int32),
                        dst[order].astype(np.int32),
                        size[order].astype(np.int64),
                        arr[order].astype(np.int32),
                        workload, load, a.slot_bytes)


def incast(fan_in: int, burst_bytes: int, *, n_hosts: int,
           slot_bytes: int = 256, dst: int = 0, n_bursts: int = 1,
           period_slots: int = 2000, first_slot: int = 0,
           background: str | None = None, background_load: float = 0.0,
           n_background: int = 0, seed: int = 0) -> MessageTable:
    """Fan-in burst scenario (paper Fig. 14 shape).

    Every ``period_slots`` (starting at ``first_slot``), ``fan_in``
    distinct senders each emit one ``burst_bytes`` response to ``dst``
    simultaneously — the application issued a request to ``fan_in``
    servers and all replies collide at one downlink. Senders are chosen
    round-robin over the other hosts so bursts span racks under any
    rack partition. With ``background``/``background_load``/
    ``n_background`` set, a Poisson workload table is overlaid.
    """
    return WorkloadSpec(
        kind="incast", fan_in=fan_in, burst_bytes=burst_bytes, dst=dst,
        n_bursts=n_bursts, period_slots=period_slots,
        first_slot=first_slot, background=background,
        background_load=background_load, n_background=n_background,
        seed=seed).build(n_hosts=n_hosts, slot_bytes=slot_bytes)


def _incast_impl(ws: WorkloadSpec, n_hosts: int,
                 slot_bytes: int) -> MessageTable:
    fan_in, dst, seed = ws.fan_in, ws.dst, ws.seed
    if not 1 <= fan_in <= n_hosts - 1:
        raise ValueError(f"incast fan_in must be in [1, n_hosts-1], got "
                         f"{fan_in} with n_hosts={n_hosts}")
    others = np.array([h for h in range(n_hosts) if h != dst], np.int32)
    rng = np.random.default_rng(seed)
    srcs, arrs = [], []
    for b in range(ws.n_bursts):
        start = int(rng.integers(len(others)))      # rotate the sender set
        sel = others[(start + np.arange(fan_in)) % len(others)]
        srcs.append(sel)
        arrs.append(np.full(fan_in, ws.first_slot + b * ws.period_slots))
    src = np.concatenate(srcs).astype(np.int32)
    arr = np.concatenate(arrs).astype(np.int32)
    tbl = MessageTable(src, np.full_like(src, dst),
                       np.full(len(src), ws.burst_bytes, np.int64),
                       arr, f"incast{fan_in}x{ws.burst_bytes}", 0.0,
                       slot_bytes)
    if ws.n_background and ws.background:
        bg = make_messages(ws.background, n_hosts=n_hosts,
                           load=ws.background_load,
                           n_messages=ws.n_background,
                           slot_bytes=slot_bytes, seed=seed + 1)
        tbl = merge_tables(bg, tbl, workload=f"incast+{ws.background}",
                           load=ws.background_load)
    return tbl


def hotspot(workload: str, *, n_hosts: int, load: float, n_messages: int,
            slot_bytes: int = 256, hot_fraction: float = 0.5,
            n_hot: int = 1, seed: int = 0) -> MessageTable:
    """Skewed destination popularity: ``hot_fraction`` of all messages
    are redirected to a hot set of ``n_hot`` hosts (the first ``n_hot``
    host ids), the rest keep their uniform destinations. Sizes and
    arrivals come from the base Poisson workload unchanged."""
    return WorkloadSpec(
        kind="hotspot", workload=workload, load=load,
        n_messages=n_messages, hot_fraction=hot_fraction, n_hot=n_hot,
        seed=seed).build(n_hosts=n_hosts, slot_bytes=slot_bytes)


def _hotspot_impl(ws: WorkloadSpec, n_hosts: int,
                  slot_bytes: int) -> MessageTable:
    if not 0.0 <= ws.hot_fraction <= 1.0:
        raise ValueError(f"hot_fraction must be in [0, 1], got "
                         f"{ws.hot_fraction}")
    if not 1 <= ws.n_hot < n_hosts:
        raise ValueError(f"n_hot must be in [1, n_hosts), got {ws.n_hot}")
    tbl = make_messages(ws.workload, n_hosts=n_hosts, load=ws.load,
                        n_messages=ws.n_messages, slot_bytes=slot_bytes,
                        seed=ws.seed, max_bytes=ws.max_bytes)
    rng = np.random.default_rng(ws.seed + 0x5EED)
    redirect = rng.random(ws.n_messages) < ws.hot_fraction
    hot_dst = rng.integers(0, ws.n_hot, ws.n_messages).astype(np.int32)
    dst = np.where(redirect, hot_dst, tbl.dst).astype(np.int32)
    # a hot host never sends to itself: bounce to the next host id
    clash = dst == tbl.src
    dst[clash] = (dst[clash] + 1) % n_hosts
    return MessageTable(tbl.src, dst, tbl.size, tbl.arrival_slot,
                        f"hotspot:{ws.workload}", ws.load, slot_bytes)


def shuffle(*, n_hosts: int, bytes_per_pair: int, slot_bytes: int = 256,
            spread_slots: int = 0, seed: int = 0) -> MessageTable:
    """All-to-all shuffle: every ordered pair (i, j), i != j, exchanges
    one ``bytes_per_pair`` message. Arrivals are uniform over
    ``spread_slots`` (0 = everything starts at slot 0) in a seeded
    random pair order — the map-reduce shuffle that saturates
    oversubscribed TOR uplinks."""
    return WorkloadSpec(
        kind="shuffle", bytes_per_pair=bytes_per_pair,
        spread_slots=spread_slots, seed=seed).build(
            n_hosts=n_hosts, slot_bytes=slot_bytes)


def _shuffle_impl(ws: WorkloadSpec, n_hosts: int,
                  slot_bytes: int) -> MessageTable:
    pairs = np.array([(i, j) for i in range(n_hosts)
                      for j in range(n_hosts) if i != j], np.int32)
    rng = np.random.default_rng(ws.seed)
    order = rng.permutation(len(pairs))
    pairs = pairs[order]
    if ws.spread_slots > 0:
        arr = np.sort(rng.integers(0, ws.spread_slots, len(pairs)))
    else:
        arr = np.zeros(len(pairs), np.int64)
    return MessageTable(pairs[:, 0], pairs[:, 1],
                        np.full(len(pairs), ws.bytes_per_pair, np.int64),
                        arr.astype(np.int32), "shuffle", 1.0, slot_bytes)


# ------------------------------------------------- failure scenarios ------
# Compatibility wrappers: failure scenarios are FabricConfig.with_*
# methods now (they transform the fabric, so they live on it).

def lossy_fabric(fab: FabricConfig, **kw) -> FabricConfig:
    """Thin wrapper over :meth:`FabricConfig.with_lossy`."""
    return fab.with_lossy(**kw)


def uplink_failure(fab: FabricConfig, **kw) -> FabricConfig:
    """Thin wrapper over :meth:`FabricConfig.with_uplink_failure`."""
    return fab.with_uplink_failure(**kw)


def tor_failure(fab: FabricConfig, **kw) -> FabricConfig:
    """Thin wrapper over :meth:`FabricConfig.with_tor_failure`."""
    return fab.with_tor_failure(**kw)


__all__ = ["incast", "hotspot", "shuffle", "merge_tables",
           "lossy_fabric", "uplink_failure", "tor_failure"]
