"""Two-tier leaf-spine fabric model (paper §5.2 topology; DESIGN.md §5).

The paper's large-scale results run on a two-level leaf-spine network:
144 hosts in 9 racks, each TOR connected to every spine, with a
configurable oversubscription ratio at the TOR uplinks. This module
models that fabric as an extra, fully vectorized queueing tier inside
the simulator's ``lax.scan``:

  host NIC ──> TOR ──(same rack: leaf switching)──> dst downlink queue
                └──(cross rack: UPLINK PRIORITY QUEUE ──> spine)──┘

- **Uplink queues.** Each TOR has ``n_uplinks = max(1, round(rack_size
  / oversub))`` uplinks, one per spine, each draining one chunk per
  slot with the same strict-priority-then-FIFO arbitration as the
  receiver downlinks. ``oversub`` > 1 therefore means cross-rack
  traffic contends for less aggregate uplink bandwidth than the rack's
  hosts can offer — the congestion point Homa's grant scheduling cannot
  see directly.
- **Spine selection.** A chunk's uplink (= spine) is chosen by a
  seeded, deterministic integer hash of ``(src, dst, msg_id, seed)``
  computed once per message in ``prepare`` — flow-level ECMP at
  per-message granularity. Same table + same seed => bit-identical
  runs; changing ``FabricConfig.seed`` reshuffles spine placement only.
- **Delays.** Intra-rack chunks keep the single-switch latency
  (``cfg.net_delay_slots``). Cross-rack chunks wait ``leaf_delay_slots``
  before uplink service (the service slot is the last wait slot), then
  ``spine_delay_slots`` more before downlink service, so an unloaded
  cross-rack chunk completes ``leaf_delay_slots + spine_delay_slots``
  after transmission. The defaults (6 + 6) equal the default
  ``net_delay_slots = 12``: an unloaded fabric reproduces the
  single-switch timing exactly.
- **Priorities.** Uplink queues honour the same wire priority the
  sender policy stamped on the chunk (``SenderPolicy.chunk_prio``), so
  Homa's unscheduled/scheduled levels shape queueing at *both* tiers.

``FabricConfig(None)`` (or ``SimConfig.fabric=None``, the default)
disables the tier entirely: the scan carries no uplink state and the
program is bit-identical to the single-switch simulator (tested against
a golden snapshot in ``tests/test_fabric.py``).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.protocols import BIG, I32
from repro.core.faults import (FaultConfig, inject_losses, forward_losses,
                               link_down_mask, select_uplink)
from repro.kernels.arbiter import dispatch
from repro.kernels.arbiter.ref import priority_arbiter_ref

ROUTING_POLICIES = ("ecmp", "flowlet", "adaptive")


@dataclasses.dataclass(frozen=True)
class FabricConfig:
    """Leaf-spine topology parameters (hashable: a static jit argument).

    ``FabricConfig(None)`` is the disabled sentinel — single-switch
    behavior, bit-identical to ``SimConfig.fabric=None``.
    """
    racks: int | None = None        # None disables the fabric tier
    oversub: float = 2.0            # rack offered bw : uplink bw ratio
    leaf_delay_slots: int = 6       # host NIC -> TOR uplink service
    spine_delay_slots: int = 6      # uplink service -> dst downlink service
    up_cap: int = 512               # per-uplink buffered chunks
    seed: int = 0                   # spine-hash seed (ECMP placement)
    # spine selection policy (DESIGN.md §7): "ecmp" is the static
    # per-message hash (today's behavior); "flowlet" re-hashes every
    # flowlet_slots; "adaptive" picks the least-loaded live uplink
    routing: str = "ecmp"
    flowlet_slots: int = 64         # flowlet epoch length (~1.7 RTT)
    # fault injection + loss recovery (repro.core.faults); None keeps
    # the scan loss-free and bit-identical to the pre-fault simulator
    faults: FaultConfig | None = None

    def __post_init__(self):
        # JSON round-trip convenience: accept a plain dict for faults
        if isinstance(self.faults, dict):
            object.__setattr__(self, "faults", FaultConfig(**self.faults))

    @property
    def enabled(self) -> bool:
        return self.racks is not None

    def validate(self, n_hosts: int) -> None:
        if not self.enabled:
            return
        if self.racks < 1:
            raise ValueError(f"FabricConfig.racks must be >= 1, got "
                             f"{self.racks}")
        if n_hosts % self.racks:
            raise ValueError(
                f"n_hosts={n_hosts} is not divisible by racks={self.racks}; "
                f"the leaf-spine model needs equal-size racks")
        if self.oversub <= 0:
            raise ValueError(f"FabricConfig.oversub must be > 0, got "
                             f"{self.oversub}")
        if self.leaf_delay_slots < 0:
            raise ValueError("FabricConfig.leaf_delay_slots must be >= 0")
        if self.spine_delay_slots < 1:
            raise ValueError(
                "FabricConfig.spine_delay_slots must be >= 1 (a chunk "
                "cannot traverse uplink and downlink in the same slot)")
        if self.up_cap < 1:
            raise ValueError("FabricConfig.up_cap must be >= 1")
        if self.routing not in ROUTING_POLICIES:
            raise ValueError(
                f"unknown routing policy {self.routing!r}; available: "
                f"{list(ROUTING_POLICIES)}")
        if self.flowlet_slots < 1:
            raise ValueError("FabricConfig.flowlet_slots must be >= 1")
        if self.faults is not None:
            self.faults.validate(self, n_hosts)

    # ---- derived topology (python ints: shape parameters for the scan)

    def rack_size(self, n_hosts: int) -> int:
        return n_hosts // self.racks

    def n_uplinks(self, n_hosts: int) -> int:
        """Uplinks per TOR (= number of spines each TOR reaches). The
        oversubscription ratio is rack_size : n_uplinks."""
        return max(1, int(round(self.rack_size(n_hosts) / self.oversub)))

    def n_uplinks_total(self, n_hosts: int) -> int:
        return self.racks * self.n_uplinks(n_hosts)

    # ---- failure-scenario constructors (DESIGN.md §7). Each returns a
    # new frozen config with the fault layered onto any existing
    # FaultConfig; re-exported as module functions from
    # repro.core.scenarios for compatibility.

    def with_faults(self, **fault_kw) -> "FabricConfig":
        """New config with ``fault_kw`` merged into the fault layer."""
        if not self.enabled:
            raise ValueError("failure scenarios need an enabled fabric "
                             "(FabricConfig with racks set): faults model "
                             "loss on leaf-spine links")
        base = dataclasses.asdict(self.faults) \
            if self.faults is not None else {}
        return dataclasses.replace(
            self, faults=FaultConfig(**{**base, **fault_kw}))

    def with_lossy(self, *, up_loss: float = 0.0, down_loss: float = 0.0,
                   ge_p_gb: float = 0.0, ge_p_bg: float = 0.05,
                   ge_loss: float = 0.5, seed: int = 0) -> "FabricConfig":
        """Steady-state lossy links: Bernoulli uplink/downlink chunk
        loss, optionally with a Gilbert-Elliott burst component."""
        return self.with_faults(up_loss=up_loss, down_loss=down_loss,
                                ge_p_gb=ge_p_gb, ge_p_bg=ge_p_bg,
                                ge_loss=ge_loss, seed=seed)

    def with_uplink_failure(self, *, uplink: int, start: int,
                            end: int) -> "FabricConfig":
        """One TOR uplink black-holes all traffic for ``[start, end)``
        slots — the scenario where routing policy dominates: static ECMP
        keeps hashing flows into the dead spine until the window lifts."""
        prior = self.faults.link_fail if self.faults is not None else ()
        return self.with_faults(link_fail=prior + ((uplink, start, end),))

    def with_tor_failure(self, *, rack: int, start: int,
                         end: int) -> "FabricConfig":
        """A whole TOR fails for ``[start, end)`` slots: the rack's
        uplinks and host downlinks all go dark; recovery timeouts must
        carry every in-flight message across the window."""
        prior = self.faults.tor_fail if self.faults is not None else ()
        return self.with_faults(tor_fail=prior + ((rack, start, end),))


def spine_hash(src: np.ndarray, dst: np.ndarray, msg_id: np.ndarray,
               seed: int, n_uplinks: int) -> np.ndarray:
    """Deterministic per-message spine choice in ``[0, n_uplinks)``.

    An xorshift-multiply mix of (src, dst, msg_id, seed) — the model's
    stand-in for ECMP 5-tuple hashing, at per-message granularity so
    repeated src->dst pairs spread across spines like distinct RPCs do.
    """
    # seed term mixed in python ints then masked: a numpy scalar-scalar
    # uint32 product warns on the (intended) wraparound
    seed_mix = np.uint32((seed * 0x27D4EB2F) & 0xFFFFFFFF)
    h = (np.asarray(src, np.uint32) * np.uint32(0x9E3779B1)
         ^ np.asarray(dst, np.uint32) * np.uint32(0x85EBCA77)
         ^ np.asarray(msg_id, np.uint32) * np.uint32(0xC2B2AE3D)
         ^ seed_mix)
    h ^= h >> np.uint32(15)
    h *= np.uint32(0x2C1B3C6D)
    h ^= h >> np.uint32(12)
    return (h % np.uint32(n_uplinks)).astype(np.int32)


# ------------------------------------------------------- ring primitives ---
# Shared by downlink and uplink tiers: a (R, cap) pool of ring buffers
# with occupancy-based insertion and strict-priority / FIFO drain.

def ring_insert(msg_a, prio_a, seq_a, valid_a, row, ok, msg, prio, seq):
    """Insert up to ``len(row)`` chunks into per-row rings.

    Item i goes into ring ``row[i]`` iff ``ok[i]``; multiple items may
    target the same row in one slot (they take consecutive free slots in
    input order). A chunk is dropped only when its ring is actually
    full. Returns the four updated ring arrays plus the dropped count.
    """
    R = valid_a.shape[0]
    n = row.shape[0]
    rows = jnp.where(ok, row, R)                              # sentinel R
    same = (rows[:, None] == rows[None, :]) & ok[None, :] & ok[:, None]
    ar = jnp.arange(n)
    rank = jnp.sum(same & (ar[None, :] < ar[:, None]), axis=1)
    # (r+1)-th free slot per row: the cumsum of free slots is
    # nondecreasing, so a binary search per item replaces the full
    # (R, cap, n) match table (see sim.py history).
    c = jnp.cumsum(~valid_a, axis=1)
    c_row = c[jnp.minimum(rows, R - 1)]                       # (n, cap)
    room = c_row[:, -1] > rank
    okw = ok & room
    pos = jax.vmap(jnp.searchsorted)(c_row, rank + 1)         # (n,)
    # suppressed writes go out of bounds (mode="drop"): an in-bounds
    # no-op write could race a genuine insertion at the same location
    idx = (jnp.where(okw, rows, R), jnp.where(okw, pos, 0))
    return (msg_a.at[idx].set(msg, mode="drop"),
            prio_a.at[idx].set(prio, mode="drop"),
            seq_a.at[idx].set(seq, mode="drop"),
            valid_a.at[idx].set(jnp.ones_like(okw), mode="drop"),
            jnp.sum(ok & ~room))


def ring_drain_select(prio_a, seq_a, eligible):
    """Pick one chunk per row: strict priority, FIFO (seq) within level.
    Returns ``(slot_idx, any_elig, pmin)`` — the winning slot per row,
    whether the row drained anything, and the winning priority. The
    math lives in ``kernels.arbiter.ref.priority_arbiter_ref`` — ONE
    reference oracle, shared with the backend dispatcher, so the sim
    and the standalone kernel tests cannot drift apart."""
    pmin, slot_idx = priority_arbiter_ref(prio_a, seq_a, eligible)
    return slot_idx, pmin < BIG, pmin


def drain_select(prio_a, seq_a, eligible, *, backend: str = "reference",
                 interpret: bool | None = None):
    """Backend-dispatched :func:`ring_drain_select` (DESIGN.md §6): the
    simulator's per-slot arbitration hot spot, routable to the Pallas
    ``priority_arbiter`` kernel via ``SimConfig.backend``. Both paths
    are bit-identical — winner slot, eligibility, and priority — for
    ragged shapes and all-ineligible rows (property-tested in
    ``tests/test_kernels.py``)."""
    bp, bi = dispatch.arbitrate(prio_a, seq_a, eligible, backend=backend,
                                interpret=interpret)
    return bi, bp < BIG, bp


# ------------------------------------------------------- fabric stages -----

def init_fabric_state(cfg) -> dict:
    """Uplink-tier scan state; only fabric-enabled configs carry it."""
    fab = cfg.fabric
    U, ucap = fab.n_uplinks_total(cfg.n_hosts), fab.up_cap
    return {
        "u_msg": jnp.full((U, ucap), -1, I32),
        "u_prio": jnp.full((U, ucap), BIG, I32),
        "u_seq": jnp.full((U, ucap), BIG, I32),
        "u_valid": jnp.zeros((U, ucap), bool),
        "u_busy": jnp.zeros((U,), I32),
        "u_q_sum": jnp.zeros((U,), jnp.float32),
        "u_q_max": jnp.zeros((U,), I32),
        "u_lost": jnp.zeros((), I32),
    }


def route_chunks(cfg, st, S, cm, has, dsts, prio_chunk, now):
    """Route this slot's transmitted chunks into the first queueing tier:
    same-rack chunks switch at the leaf straight into the destination
    downlink ring; cross-rack chunks enter their TOR's hashed uplink
    queue. Returns updated state."""
    fab = cfg.fabric
    H = cfg.n_hosts
    rs = fab.rack_size(H)
    n_up = fab.n_uplinks(H)
    src_rack = jnp.arange(H, dtype=I32) // rs
    dst_rack = jnp.minimum(dsts, H - 1) // rs
    local = has & (src_rack == dst_rack)
    remote = has & (src_rack != dst_rack)

    if fab.routing == "ecmp":
        urow = src_rack * n_up + S["spine"][cm]
    else:
        urow = select_uplink(cfg, st, S, cm, src_rack, now)
    if fab.faults is not None:
        local, remote, st = inject_losses(cfg, st, cm, local, remote,
                                          dsts, urow, now)

    r_msg, r_prio, r_seq, r_valid, d_drop = ring_insert(
        st["r_msg"], st["r_prio"], st["r_seq"], st["r_valid"],
        dsts, local, cm, prio_chunk, jnp.full_like(dsts, now))

    u_msg, u_prio, u_seq, u_valid, u_drop = ring_insert(
        st["u_msg"], st["u_prio"], st["u_seq"], st["u_valid"],
        urow, remote, cm, prio_chunk, jnp.full_like(urow, now))

    return {**st,
            "r_msg": r_msg, "r_prio": r_prio, "r_seq": r_seq,
            "r_valid": r_valid,
            "u_msg": u_msg, "u_prio": u_prio, "u_seq": u_seq,
            "u_valid": u_valid,
            "lost": st["lost"] + d_drop,
            "u_lost": st["u_lost"] + u_drop}


def uplink_drain(cfg, st, S, now, pre=None):
    """Drain at most one chunk per TOR uplink (strict priority, FIFO
    within level) and forward it across its spine into the destination
    downlink ring, where it becomes eligible after ``spine_delay_slots``.
    Returns updated state.

    ``pre`` is an optional pre-solved ``(slot_idx, any_e, prio)`` winner
    triple from the ``pallas_fused`` backend, which arbitrates all of a
    slot's stages in one kernel at slot start (DESIGN.md §11). The hoist
    is bit-identical because this slot's ``route_chunks`` insertions
    carry ``u_seq == now`` and ``leaf_delay_slots >= 1`` (enforced by
    ``sim._fused_precompute``) keeps them ineligible until the next
    slot — and ``ring_insert`` never overwrites a valid (winning) slot."""
    fab = cfg.fabric
    H = cfg.n_hosts
    M = S["size"].shape[0]
    U = st["u_valid"].shape[0]

    eligible = st["u_valid"] & (st["u_seq"] + fab.leaf_delay_slots <= now)
    fl = fab.faults
    if fl is not None and (fl.link_fail or fl.tor_fail):
        # a failed uplink black-holes its queue for the window: chunks
        # already buffered there neither drain nor get re-routed
        eligible = eligible & ~link_down_mask(cfg, now)[:, None]
    if pre is not None:
        slot_idx, any_e, _ = pre
    else:
        slot_idx, any_e, _ = drain_select(st["u_prio"], st["u_seq"],
                                          eligible, backend=cfg.backend,
                                          interpret=cfg.pallas_interpret)
    uidx = (jnp.arange(U), slot_idx)
    msg = jnp.where(any_e, st["u_msg"][uidx], M)
    prio = st["u_prio"][uidx]
    u_valid = st["u_valid"].at[uidx].set(
        jnp.where(any_e, False, st["u_valid"][uidx]))

    # forward into the downlink ring with a *virtual* enqueue time such
    # that (seq + net_delay_slots <= t) fires at t = now + spine_delay:
    # the downlink's single eligibility rule then covers both tiers, and
    # FIFO order within a priority level remains arrival-time order at
    # the destination TOR.
    dst = jnp.where(any_e, S["dst"][jnp.minimum(msg, M - 1)], H)
    vseq = jnp.full((U,), now + fab.spine_delay_slots - cfg.net_delay_slots,
                    I32)
    ins_ok = any_e
    if fl is not None and (fl.down_loss > 0 or fl.tor_fail):
        # last-hop loss point: the chunk left the uplink (it still counts
        # toward u_busy) but dies on the spine->TOR->host leg
        ins_ok, st = forward_losses(cfg, st, msg, dst, any_e, now)
    r_msg, r_prio, r_seq, r_valid, d_drop = ring_insert(
        st["r_msg"], st["r_prio"], st["r_seq"], st["r_valid"],
        dst, ins_ok, msg, prio, vseq)

    qlen = eligible.sum(axis=1) - any_e.astype(I32)
    out = {**st,
           "r_msg": r_msg, "r_prio": r_prio, "r_seq": r_seq,
           "r_valid": r_valid, "u_valid": u_valid,
           "lost": st["lost"] + d_drop,
           "u_busy": st["u_busy"] + any_e.astype(I32),
           "u_q_sum": st["u_q_sum"] + qlen.astype(jnp.float32),
           "u_q_max": jnp.maximum(st["u_q_max"], qlen)}
    if getattr(cfg, "trace_on", False):
        # telemetry tap (DESIGN.md §8): running uplink-tier per-priority
        # drain counter, sampled into the strided series by capture_slot
        dp = jnp.where(any_e, jnp.minimum(prio, cfg.n_prios - 1), 0)
        out["tr_uprio_c"] = st["tr_uprio_c"].at[dp].add(
            jnp.where(any_e, 1, 0), mode="drop")
    return out


__all__ = ["FabricConfig", "FaultConfig", "ROUTING_POLICIES", "spine_hash",
           "ring_insert", "ring_drain_select", "drain_select",
           "init_fabric_state", "route_chunks", "uplink_drain"]
