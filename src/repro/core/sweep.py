"""Device-sharded mega-sweeps with streaming statistics (DESIGN.md §9).

``run_sweep`` historically vmapped every run onto one device and pulled
each run's full per-message arrays back to the host before computing
percentiles — a paper-scale grid (6 protocols x loads x oversubs x
seeds, Figs. 10/11/14) neither fits in memory nor uses more than one
accelerator. This module is the scale layer behind ``run_sweep(cfg,
spec)``:

**SweepSpec** — one frozen description of the whole sweep (tables or
``seeds`` + ``workload`` + ``load``, per-table alloc/unsched ablation
lists, ``shared_alloc``) plus the scale knobs: ``shard`` (device count
for a ``shard_map`` layer over the run axis), ``chunk_slots`` (the time
scan runs chunk-by-chunk so streaming accumulators fold at bounded
intervals), and ``streaming`` (a :class:`StreamSpec`).

**Sharding.** Runs are grouped by the scan's static parameters
``(table length, scheduled levels)`` — :func:`group_runs`, the single
grouping implementation shared with ``benchmarks/common.sim_sweep`` —
stacked, padded to a device multiple (replicating the last run; padding
rows are dropped after the gather), and executed as
``shard_map(vmap(one_run))`` over a 1-D ``runs`` mesh. Every run is
independent, so sharded results are bit-identical to the single-device
vmap, which is itself bit-identical to sequential ``simulate`` calls.
Validated on CPU via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

**Chunked scan.** ``chunk_slots=c`` nests the slot scan: an outer
``lax.scan`` over chunks, an inner scan over the ``c`` slots of each
chunk, with the global slot index reconstructed as ``chunk * c + i`` so
every mechanism (grant history rings, telemetry strides, fault windows)
sees exactly the slot numbers the flat scan would — the chunked program
is the same step sequence and therefore bit-identical. Streaming
accumulators ride the outer carry and fold once per chunk.

**Fused backend under vmap.** With ``backend="pallas_fused"``
(DESIGN.md §11) the vmap over the run axis does NOT un-fuse the
per-slot mega-kernel into per-lane calls: the fused entry point
carries a ``jax.custom_batching.custom_vmap`` rule that rewrites the
batched call into a single ``grid=(B,)`` kernel — one launch per slot
for the whole run batch, on both the fast path and this chunked path.
Nothing in this module special-cases it; the rule lives in
``kernels.arbiter.fused``.

**Streaming stats.** With ``streaming`` on, a run's slowdowns are binned
into a fixed log-spaced histogram *inside* the compiled program (size
bucket x slowdown bucket), and only O(buckets) scalars per run are
gathered to the host — never the (N, M) per-message arrays. Percentile
estimates from the histogram carry a documented relative error bound of
half a bucket in log space (:meth:`StreamSpec.rel_err_bound`, ~0.9% at
the defaults), regression-gated in tests/test_sweep.py. Queue/busy/
priority stats reduce exactly (they are already running counters in the
scan state), and captured traces reduce device-side via
``telemetry.reduce_state``.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec

from repro.core.priorities import allocate_priorities
from repro.core.protocols import get_protocol, I32
from repro.core.workloads import MessageTable, WorkloadSpec, make_messages
from repro.core import telemetry

# message-size bucket upper bounds (bytes) for streaming per-size
# percentiles; 1000 B is the "small message" boundary every summary uses
DEFAULT_SIZE_EDGES = (256, 1_000, 4_096, 16_384, 65_536, 262_144,
                      1_048_576)


# ================================================================ specs ==

@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """Streaming-accumulator parameters (hashable: rides the jit cache
    key). Slowdowns are binned into ``n_buckets`` log-spaced buckets
    spanning ``[1, max_slowdown)`` (the last bucket absorbs anything
    larger); sizes into ``len(size_edges) + 1`` buckets."""
    n_buckets: int = 512
    max_slowdown: float = 1e4
    size_edges: tuple = DEFAULT_SIZE_EDGES
    small_bytes: int = 1_000            # must be one of size_edges
    warmup_frac: float = 0.0            # drop first fraction of arrivals

    def __post_init__(self):
        if self.n_buckets < 2:
            raise ValueError(f"StreamSpec.n_buckets must be >= 2, got "
                             f"{self.n_buckets}")
        if self.max_slowdown <= 1.0:
            raise ValueError(f"StreamSpec.max_slowdown must be > 1, got "
                             f"{self.max_slowdown}")
        edges = tuple(int(e) for e in self.size_edges)
        if list(edges) != sorted(set(edges)):
            raise ValueError(f"StreamSpec.size_edges must be strictly "
                             f"increasing, got {self.size_edges}")
        object.__setattr__(self, "size_edges", edges)
        if self.small_bytes not in edges:
            raise ValueError(
                f"StreamSpec.small_bytes={self.small_bytes} must be one "
                f"of size_edges {edges} so the small-message percentile "
                f"is a bucket boundary, not an approximation")
        if not 0.0 <= self.warmup_frac < 1.0:
            raise ValueError(f"StreamSpec.warmup_frac must be in [0, 1), "
                             f"got {self.warmup_frac}")

    @property
    def n_size_buckets(self) -> int:
        return len(self.size_edges) + 1

    @property
    def bucket_ratio(self) -> float:
        """Multiplicative width of one slowdown bucket."""
        return self.max_slowdown ** (1.0 / (self.n_buckets - 1))

    @property
    def rel_err_bound(self) -> float:
        """Documented relative error of a percentile estimate vs any
        sample in its bucket: half a bucket in log space."""
        return math.sqrt(self.bucket_ratio) - 1.0


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """One frozen description of a whole sweep — the single argument of
    ``run_sweep(cfg, spec)`` (DESIGN.md §9).

    Exactly one run source: ``tables`` (MessageTables, lengths may
    differ — runs group by static parameters), or ``seeds`` +
    ``workload`` + ``load`` (one synthesized table per seed).
    ``workload`` also accepts a full
    :class:`~repro.core.workloads.WorkloadSpec` (any kind — scenarios
    included); it carries its own load/shape parameters, so ``load``
    must then stay ``None`` and each seed re-seeds the spec.
    ``alloc`` / ``unsched_limit_bytes`` accept a single value or one
    entry per table (priority-ablation sweeps, Figs. 17/18/20).

    Scale knobs: ``shard`` = False (one device) | True (all available
    devices) | int (first n devices); ``chunk_slots`` nests the time
    scan (bit-identical; required for streaming folds at bounded
    intervals); ``streaming`` = False | True (default StreamSpec) | a
    StreamSpec — results become :class:`SweepStats` instead of
    ``SimResult`` and only O(buckets) per run ever reaches the host.
    """
    tables: tuple[MessageTable, ...] | None = None
    seeds: tuple[int, ...] | None = None
    workload: str | WorkloadSpec | None = None
    load: float | None = None
    n_messages: int = 2000
    alloc: Any = None
    unsched_limit_bytes: Any = None
    shared_alloc: bool = False
    shard: bool | int = False
    chunk_slots: int | None = None
    streaming: bool | StreamSpec = False
    return_state: bool = False

    def __post_init__(self):
        if self.tables is not None:
            object.__setattr__(self, "tables", tuple(self.tables))
        elif self.seeds is None or self.workload is None \
                or (self.load is None
                    and not isinstance(self.workload, WorkloadSpec)):
            raise ValueError("SweepSpec needs `tables` or "
                             "(`seeds`, `workload`, `load`) — "
                             "`workload` may be a WorkloadSpec carrying "
                             "its own load/shape parameters")
        if isinstance(self.workload, WorkloadSpec) \
                and self.load is not None:
            raise ValueError("load is part of the WorkloadSpec; don't "
                             "pass SweepSpec.load alongside one")
        if self.seeds is not None:
            object.__setattr__(self, "seeds",
                               tuple(int(s) for s in self.seeds))
        if self.chunk_slots is not None and self.chunk_slots < 1:
            raise ValueError(f"SweepSpec.chunk_slots must be >= 1, got "
                             f"{self.chunk_slots}")
        if self.streaming is True:
            object.__setattr__(self, "streaming", StreamSpec())
        if self.stream is not None and self.return_state:
            raise ValueError("streaming sweeps never materialize scan "
                             "state; return_state=True needs an exact "
                             "(non-streaming) sweep")

    @property
    def stream(self) -> StreamSpec | None:
        return self.streaming if isinstance(self.streaming, StreamSpec) \
            else None

    def resolve_tables(self, cfg) -> list[MessageTable]:
        if self.tables is not None:
            return list(self.tables)
        if isinstance(self.workload, WorkloadSpec):
            return [self.workload.with_seed(s).build(
                n_hosts=cfg.n_hosts, slot_bytes=cfg.slot_bytes)
                for s in self.seeds]
        return [make_messages(self.workload, n_hosts=cfg.n_hosts,
                              load=self.load, n_messages=self.n_messages,
                              slot_bytes=cfg.slot_bytes, seed=s)
                for s in self.seeds]


def resolve_devices(shard: bool | int) -> int:
    """``shard`` knob -> concrete device count (validated)."""
    if shard is False or shard is None:
        return 1
    avail = len(jax.devices())
    n = avail if shard is True else int(shard)
    if n < 1 or n > avail:
        raise ValueError(f"SweepSpec.shard={shard!r} asks for {n} "
                         f"devices but {avail} are available "
                         f"(XLA_FLAGS=--xla_force_host_platform_"
                         f"device_count=N forces N virtual CPU devices)")
    return n


def group_runs(keys: list[tuple]) -> dict[tuple, list[int]]:
    """Group run indices by their static scan parameters — THE grouping
    implementation, shared by ``run_sweep`` and
    ``benchmarks/common.sim_sweep`` (each distinct key costs one jit
    compilation; input order is preserved within groups)."""
    groups: dict[tuple, list[int]] = {}
    for i, k in enumerate(keys):
        groups.setdefault(k, []).append(i)
    return groups


# ================================================= streaming primitives ==

def sd_bucket_edges(stream: StreamSpec) -> np.ndarray:
    """Interior bucket edges (n_buckets - 1,): bucket b spans
    ``[r^b, r^(b+1))`` with r = :meth:`StreamSpec.bucket_ratio` (bucket 0
    starts at slowdown 1.0; the last bucket is open-ended)."""
    B = stream.n_buckets
    return (stream.bucket_ratio
            ** np.arange(1, B, dtype=np.float64)).astype(np.float32)


def bucket_mid(stream: StreamSpec, b) -> np.ndarray:
    """Geometric midpoint of slowdown bucket ``b`` (the estimator's
    representative value; error vs any member <= rel_err_bound)."""
    return stream.bucket_ratio ** (np.asarray(b, np.float64) + 0.5)


def streaming_hist(slowdowns, stream: StreamSpec) -> np.ndarray:
    """Host-side mirror of the device binning (float32 + searchsorted,
    exactly as the scan computes it) — the reference for the property
    tests pinning estimator error."""
    sd = np.asarray(slowdowns, np.float32)
    b = np.searchsorted(sd_bucket_edges(stream), sd, side="right")
    b = np.clip(b, 0, stream.n_buckets - 1)
    return np.bincount(b, minlength=stream.n_buckets).astype(np.int64)


def percentile_from_hist(hist, stream: StreamSpec, q: float
                         ) -> float | None:
    """Percentile estimate from a slowdown histogram: the geometric
    midpoint of the bucket holding rank ``q/100 * (n-1)`` (numpy's
    linear-interpolation position). Relative error vs the exact
    percentile is bounded by :meth:`StreamSpec.rel_err_bound` plus
    interpolation discreteness at small counts."""
    h = np.asarray(hist)
    n = int(h.sum())
    if n == 0:
        return None
    rank = q / 100.0 * (n - 1)
    b = int(np.searchsorted(np.cumsum(h), rank, side="right"))
    return float(bucket_mid(stream, min(b, len(h) - 1)))


def streaming_percentile(slowdowns, q: float, stream: StreamSpec
                         ) -> float | None:
    """End-to-end host mirror: bin then estimate (test surface)."""
    return percentile_from_hist(streaming_hist(slowdowns, stream),
                                stream, q)


def _pack_aux(stream: StreamSpec | None, table: MessageTable) -> dict:
    """Per-run static arrays the streaming fold needs beside S: the
    size-bucket index of every message and the warmup-window mask."""
    if stream is None:
        return {}
    M = len(table.size)
    szb = np.searchsorted(np.asarray(stream.size_edges, np.int64),
                          table.size, side="right").astype(np.int32)
    counted = np.arange(M) >= int(M * stream.warmup_frac)
    return {"szb": jnp.asarray(szb), "counted": jnp.asarray(counted)}


def _fold_hist(stream: StreamSpec, acc, st, S, aux, lo, hi):
    """Fold messages that completed in slot window ``[lo, hi)`` into the
    flat (size-bucket x slowdown-bucket) count histogram. Completion
    slots are immutable once set, so across chunk folds every message is
    counted exactly once."""
    B, K = stream.n_buckets, stream.n_size_buckets
    comp = st["completion"]
    m = (comp >= lo) & (comp < hi) & aux["counted"]
    sd = (comp - S["arrival"] + 1).astype(jnp.float32) \
        / S["ideal"].astype(jnp.float32)
    b = jnp.searchsorted(jnp.asarray(sd_bucket_edges(stream)), sd,
                         side="right")
    flat = aux["szb"] * B + jnp.clip(b, 0, B - 1)
    return acc + jax.ops.segment_sum(m.astype(I32), flat,
                                     num_segments=K * B)


def _device_summary(cfg, st, acc) -> dict:
    """Reduce one run's final scan state to the streaming gather set —
    O(buckets) scalars; the (M,) / (H, cap) state never leaves the
    device. Counter reductions are exact (ints); only the histogram is
    an approximation."""
    out = {
        "hist": acc,
        "n_complete": (st["completion"] >= 0).sum().astype(I32),
        "busy": st["busy"].sum(), "wasted": st["wasted"].sum(),
        "uplink_busy": st["uplink_busy"].sum(),
        "q_sum": st["q_sum"].sum(), "q_max": st["q_max"].max(),
        "prio_drained": st["prio_drained"],
        "lost": st["lost"] + (st["u_lost"] if cfg.fabric_on else 0),
    }
    if cfg.fabric_on:
        out["u_busy"] = st["u_busy"].sum()
    if cfg.faults_on:
        out["f_lost"] = st["f_lost"]
        out["retx"] = st["retx"].sum()
    if cfg.host_tx_on:
        # float32: summed micro-slot work across hosts can pass 2**31
        out["h_tx_work"] = st["h_tx_work_q"].sum(dtype=jnp.float32)
        out["h_tx_defer"] = st["h_tx_defer"].sum()
    if cfg.host_rx_on:
        out["h_rx_stall"] = st["h_rx_stall"].sum()
        out["h_rx_q_max"] = st["h_rx_q_max"].max()
    if cfg.trace_on:
        out.update(telemetry.reduce_state(cfg, st))
    return out


# ======================================================= chunked runner ==

def _scan_chunks(cfg, proto, S, aux, n_sched, st0, chunk, stream):
    """The chunked time scan: same step sequence as the flat scan (the
    global slot index is reconstructed, so bit-identity holds), with the
    streaming histogram folding once per chunk on the outer carry —
    per-fold work is O(M), carry stays O(buckets)."""
    from repro.core import sim as sim_mod
    body = functools.partial(sim_mod.step_fn, cfg, proto, S, n_sched)

    def seg(st, start, length):
        st, _ = lax.scan(lambda s, i: body(s, start + i), st,
                         jnp.arange(length, dtype=I32))
        return st

    acc0 = jnp.zeros(stream.n_size_buckets * stream.n_buckets, I32) \
        if stream is not None else ()
    if not chunk or chunk >= cfg.max_slots:
        st = seg(st0, jnp.int32(0), cfg.max_slots)
        if stream is not None:
            acc0 = _fold_hist(stream, acc0, st, S, aux, 0, cfg.max_slots)
        return st, acc0

    n_full, rem = divmod(cfg.max_slots, chunk)

    def chunk_body(carry, c):
        st, acc = carry
        start = c * chunk
        st = seg(st, start, chunk)
        if stream is not None:
            acc = _fold_hist(stream, acc, st, S, aux, start,
                             start + chunk)
        return (st, acc), None

    (st, acc), _ = lax.scan(chunk_body, (st0, acc0),
                            jnp.arange(n_full, dtype=I32))
    if rem:
        st = seg(st, jnp.int32(n_full * chunk), rem)
        if stream is not None:
            acc = _fold_hist(stream, acc, st, S, aux, n_full * chunk,
                             cfg.max_slots)
    return st, acc


@functools.partial(jax.jit, static_argnums=(0, 1, 4, 5, 6, 7))
def _sweep_batch(cfg, proto, S_stack, aux_stack, n_sched: int,
                 chunk: int | None, stream: StreamSpec | None,
                 n_dev: int):
    """One group's runs: vmap over the run axis, shard_map over the
    first ``n_dev`` devices (leading axis pre-padded to a multiple).
    Streaming runs return the reduced gather set; exact runs the full
    final states."""
    from repro.core import sim as sim_mod
    M = S_stack["size"].shape[1]
    st0 = sim_mod._init_state(cfg, proto, M)

    def one(S, aux):
        st, acc = _scan_chunks(cfg, proto, S, aux, n_sched, st0, chunk,
                               stream)
        return _device_summary(cfg, st, acc) if stream is not None else st

    def local(Ss, auxs):
        return jax.vmap(one)(Ss, auxs)

    if n_dev <= 1:
        return local(S_stack, aux_stack)
    mesh = Mesh(np.asarray(jax.devices()[:n_dev]), ("runs",))
    P = PartitionSpec("runs")
    # check_rep=False: pallas_call has no replication rule, and every
    # array here is fully partitioned along "runs" anyway.
    return shard_map(local, mesh=mesh, in_specs=(P, P),
                     out_specs=P, check_rep=False)(S_stack, aux_stack)


# ============================================================== results ==

@dataclasses.dataclass
class SweepStats:
    """One streaming run's bounded-size statistics (the SweepSpec
    ``streaming`` result type). ``hist`` is the (size buckets, slowdown
    buckets) completion-count table; everything else reduced exactly
    from the scan's running counters."""
    protocol: str
    stream: StreamSpec
    alloc: Any
    n_messages: int
    n_complete: int
    hist: np.ndarray                 # (K, B) int counts
    busy_frac: float
    wasted_frac: float
    uplink_busy_frac: float
    q_mean_bytes: float
    q_max_bytes: float
    prio_drained_bytes: np.ndarray   # (n_prios,)
    lost_chunks: int
    tor_up_busy_frac: float | None = None
    fault_lost_chunks: int | None = None
    retx_chunks: int | None = None
    host_tx_busy_frac: float | None = None
    host_tx_defer_frac: float | None = None
    host_rx_stall_frac: float | None = None
    host_rx_q_max_chunks: int | None = None
    trace_summary: dict | None = None

    @property
    def completion_rate(self) -> float:
        return self.n_complete / self.n_messages if self.n_messages \
            else 0.0

    @property
    def n_counted(self) -> int:
        """Completions inside the warmup-trimmed window (hist mass)."""
        return int(self.hist.sum())

    def percentile(self, q: float) -> float | None:
        """Streaming slowdown percentile over all counted messages
        (error <= ``stream.rel_err_bound`` in the relative sense)."""
        return percentile_from_hist(self.hist.sum(axis=0), self.stream,
                                    q)

    def percentile_small(self, q: float) -> float | None:
        """Percentile over messages smaller than ``stream.small_bytes``
        (exact split: small_bytes is a size-bucket edge)."""
        ks = int(np.searchsorted(np.asarray(self.stream.size_edges),
                                 self.stream.small_bytes, "left")) + 1
        return percentile_from_hist(self.hist[:ks].sum(axis=0),
                                    self.stream, q)

    def percentiles_by_size(self, pct: float = 99.0) -> dict:
        """Per-size-bucket percentile curve (the streaming stand-in for
        ``SimResult.percentiles_by_size``; buckets are the static
        ``size_edges``, not per-run equal-count deciles)."""
        edges = (1,) + self.stream.size_edges + (None,)
        out = {"sizes": [], "p": [], "median": [], "count": []}
        for k in range(self.stream.n_size_buckets):
            h = self.hist[k]
            cnt = int(h.sum())
            if cnt == 0:
                continue
            lo = edges[k]
            hi = edges[k + 1] or lo * 4
            out["sizes"].append(float(math.sqrt(lo * hi)))
            out["p"].append(percentile_from_hist(h, self.stream, pct))
            out["median"].append(percentile_from_hist(h, self.stream,
                                                      50.0))
            out["count"].append(cnt)
        return out

    def summary(self, *, pct: float = 99.0) -> dict:
        """JSON-safe aggregate summary (the benchmark-cache schema for
        streaming sweeps; mirrors ``SimResult.summary`` keys where the
        quantity survives reduction)."""
        r = lambda v: None if v is None else round(float(v), 6)  # noqa: E731
        return {
            "protocol": self.protocol,
            "n_complete": int(self.n_complete),
            "n_messages": int(self.n_messages),
            "completion_rate": r(self.completion_rate),
            "p99_by_size": self.percentiles_by_size(pct),
            "busy_frac": r(self.busy_frac),
            "wasted_frac": r(self.wasted_frac),
            "uplink_busy_frac": r(self.uplink_busy_frac),
            "q_mean_bytes": r(self.q_mean_bytes),
            "q_max_bytes": r(self.q_max_bytes),
            "prio_drained_bytes": [int(x) for x in
                                   self.prio_drained_bytes],
            "lost_chunks": int(self.lost_chunks),
            "p99_small": r(self.percentile_small(pct)),
            "p50_small": r(self.percentile_small(50.0)),
            "p99_all": r(self.percentile(pct)),
            "p50_all": r(self.percentile(50.0)),
            "streaming": {
                "n_buckets": self.stream.n_buckets,
                "max_slowdown": self.stream.max_slowdown,
                "rel_err_bound": r(self.stream.rel_err_bound),
                "n_counted": self.n_counted,
                "warmup_frac": self.stream.warmup_frac,
            },
            "host": None
            if self.host_tx_busy_frac is None
            and self.host_rx_stall_frac is None else {
                "tx_busy_frac": r(self.host_tx_busy_frac),
                "tx_defer_frac": r(self.host_tx_defer_frac),
                "rx_stall_frac": r(self.host_rx_stall_frac),
                "rx_q_max_chunks": self.host_rx_q_max_chunks,
            },
            "trace": self.trace_summary,
        }


def _stats_from_row(cfg, stream: StreamSpec, row: dict, alloc,
                    n_messages: int) -> SweepStats:
    """Host-side assembly of one gathered streaming row."""
    H, ms, sb = cfg.n_hosts, cfg.max_slots, cfg.slot_bytes
    trace_summary = None
    if cfg.trace_on:
        seen = int(row.get("tr_ev_seen", 0))
        cap = cfg.trace.ledger_cap
        trace_summary = {
            "stride": cfg.trace.stride,
            "samples": telemetry.n_samples(cfg),
            "n_events": min(seen, cap), "n_events_seen": seen,
            "events_dropped": max(0, seen - cap), "ledger_cap": cap,
            "q_peak_bytes": int(row["tr_q_peak"]) * sb,
            "grant_out_peak_bytes": int(row["tr_go_peak"]) * sb,
            "up_q_peak_bytes": int(row["tr_uq_peak"]) * sb
            if "tr_uq_peak" in row else None,
            "host_rx_q_peak_chunks": int(row["tr_hq_peak"])
            if "tr_hq_peak" in row else None,
            "timings": None,
        }
    from repro.core.hostmodel import QSCALE
    return SweepStats(
        protocol=cfg.protocol, stream=stream, alloc=alloc,
        n_messages=n_messages, n_complete=int(row["n_complete"]),
        hist=np.asarray(row["hist"]).reshape(stream.n_size_buckets,
                                             stream.n_buckets),
        busy_frac=float(row["busy"]) / (H * ms),
        wasted_frac=float(row["wasted"]) / (H * ms),
        uplink_busy_frac=float(row["uplink_busy"]) / (H * ms),
        q_mean_bytes=float(row["q_sum"]) / (H * ms) * sb,
        q_max_bytes=float(row["q_max"]) * sb,
        prio_drained_bytes=np.asarray(row["prio_drained"],
                                      np.int64) * sb,
        lost_chunks=int(row["lost"]),
        tor_up_busy_frac=float(row["u_busy"])
        / (cfg.fabric.n_uplinks(cfg.n_hosts) * ms)
        if cfg.fabric_on else None,
        fault_lost_chunks=int(row["f_lost"]) if cfg.faults_on else None,
        retx_chunks=int(row["retx"]) if cfg.faults_on else None,
        host_tx_busy_frac=float(row["h_tx_work"]) / (H * ms * QSCALE)
        if cfg.host_tx_on else None,
        host_tx_defer_frac=float(row["h_tx_defer"]) / (H * ms)
        if cfg.host_tx_on else None,
        host_rx_stall_frac=float(row["h_rx_stall"]) / (H * ms)
        if cfg.host_rx_on else None,
        host_rx_q_max_chunks=int(row["h_rx_q_max"])
        if cfg.host_rx_on else None,
        trace_summary=trace_summary,
    )


# =============================================================== engine ==

def run_spec(cfg, spec: SweepSpec) -> list:
    """Execute a :class:`SweepSpec`: prepare, group by static scan
    parameters, shard/chunk/stream as configured, gather, and finalize —
    results in input order. (Public entry point: ``run_sweep(cfg,
    spec)``; see that docstring for semantics.)"""
    from repro.core import sim as sim_mod
    tables = spec.resolve_tables(cfg)
    if not tables:
        return []
    proto = get_protocol(cfg.protocol)
    N = len(tables)
    stream = spec.stream

    alloc = spec.alloc
    if spec.shared_alloc and alloc is None:
        alloc = allocate_priorities(
            np.concatenate([t.size for t in tables]),
            unsched_limit=cfg.rtt_bytes, n_prios=cfg.n_prios)
    allocs = list(alloc) if isinstance(alloc, (list, tuple)) \
        else [alloc] * N
    uls = list(spec.unsched_limit_bytes) \
        if isinstance(spec.unsched_limit_bytes, (list, tuple)) \
        else [spec.unsched_limit_bytes] * N
    if len(allocs) != N or len(uls) != N:
        raise ValueError("per-table alloc/unsched_limit lists must match "
                         "the number of tables")

    prepped = []
    for t, al_i, ul_i in zip(tables, allocs, uls):
        S, al = sim_mod.prepare(cfg, t, al_i, ul_i)
        prepped.append((S, al, proto.n_sched(cfg, al)))

    groups = group_runs([(len(t.size), ns)
                         for t, (_, _, ns) in zip(tables, prepped)])
    n_dev = resolve_devices(spec.shard)
    fast = n_dev == 1 and spec.chunk_slots is None and stream is None

    results: list = [None] * N
    for (_, n_sched), idxs in groups.items():
        if fast:
            # the pre-SweepSpec program, byte for byte: one vmapped jit
            # per group, full states gathered (bit-identity anchor)
            S_stack = jax.tree.map(lambda *xs: jnp.stack(xs),
                                   *[prepped[i][0] for i in idxs])
            st_batch = jax.tree.map(
                np.asarray,
                sim_mod._run_batch(cfg, proto, S_stack, n_sched))
            out_rows = idxs
        else:
            pad = (-len(idxs)) % n_dev
            padded = idxs + [idxs[-1]] * pad
            S_stack = jax.tree.map(lambda *xs: jnp.stack(xs),
                                   *[prepped[i][0] for i in padded])
            aux_stack = jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[_pack_aux(stream, tables[i]) for i in padded]) \
                if stream is not None else {}
            st_batch = jax.tree.map(
                np.asarray,
                _sweep_batch(cfg, proto, S_stack, aux_stack, n_sched,
                             spec.chunk_slots, stream, n_dev))
            out_rows = idxs          # padding rows simply never read

        for k, i in enumerate(out_rows):
            row = jax.tree.map(lambda x: x[k], st_batch)
            if stream is not None:
                results[i] = _stats_from_row(cfg, stream, row,
                                             prepped[i][1],
                                             len(tables[i].size))
            else:
                results[i] = sim_mod._finalize(
                    cfg, tables[i], prepped[i][0], prepped[i][1], row,
                    spec.return_state, reduce_trace=True)
    return results


__all__ = ["SweepSpec", "StreamSpec", "SweepStats", "run_spec",
           "group_runs", "resolve_devices", "streaming_hist",
           "streaming_percentile", "percentile_from_hist",
           "sd_bucket_edges", "bucket_mid", "DEFAULT_SIZE_EDGES"]
