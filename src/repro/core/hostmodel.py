"""Pluggable host/NIC stage: per-packet software overhead (DESIGN.md §10).

Homa's §5.3 reports a large gap between implementation and simulation
latency, and Ousterhout's *It's Time to Replace TCP in the Datacenter*
argues the dominant cost for Homa-class transports is per-packet host
software processing — a cost a fabric-only simulator models as zero.
This module adds that cost as a swappable stage (SimBricks-style: host,
NIC, and network compose behind enforced interfaces) in front of the
existing network model, on both sides of the wire:

  send side     a per-host TX token bucket in fixed-point "micro-slots"
                (1/256 slot): every transmitted chunk charges
                ``tx_cost_slots`` of CPU time, every ``tx_batch``-th
                chunk additionally pays ``tx_batch_cost_slots``
                (interrupt coalescing / doorbell batching), and budget
                accrues while idle up to ``tx_queue_cap`` chunks' worth
                (NIC TX ring pre-fill), so bursts go out at line rate
                but the *sustained* rate is 1/cost chunks per slot.
  receive side  a per-host bounded FIFO (NIC RX ring): each chunk
                drained off the downlink enters the ring and becomes
                visible to the receiver (``recv`` — which clocks both
                grants and completion) only after ``rx_cost_slots`` of
                serialized CPU service; a full ring backpressures the
                downlink (the chunk stays queued in the network).

Everything is int32 fixed-point: ``prepare`` bounds ``max_slots``
below 2**21, so absolute micro-slot timestamps stay under 2**29.

Zero-overhead configs are structurally skipped: ``SimConfig.host=None``
and the ``ideal`` preset (all costs zero) add no arrays and no ops to
the scan, so the compiled program — and therefore every golden — is
bit-identical to the host-free simulator. A side whose costs are all
zero (``tx_on`` / ``rx_on`` False) vanishes the same way; note an
*active* RX stage adds at least one slot of latency per chunk even at
small costs, because ring entries become ready strictly after their
enqueue slot.

Host models are pluggable through an enforced interface: implement
:class:`HostModel`'s five hooks and :func:`register_host_model` it;
``HostConfig.model`` selects the implementation by name. ``"cpu"``
(:class:`CpuHostModel`, the token-bucket + RX-ring model above) ships
in-tree, with presets:

  ideal          zero overhead — the host stage compiles away
  kernel_stack   OS kernel networking: ~1 slot/chunk marginal TX cost
                 + an 8-slot interrupt batch every 8 chunks (effective
                 2 slots/chunk ≈ 0.5 line rate), 2 slots RX service
  kernel_bypass  DPDK-style polling: 0.25 slots TX, 0.5 slots RX —
                 line rate sustained, small added latency
"""
from __future__ import annotations

import abc
import dataclasses

import jax.numpy as jnp

from repro.core.protocols import I32

# fixed-point scale: micro-slots per link slot (8 fractional bits)
QSCALE = 256


@dataclasses.dataclass(frozen=True)
class HostConfig:
    """Host/NIC stage parameters (frozen, hashable -> jit-static).

    Costs are in link-slot units (1 slot = ``slot_bytes`` of wire time,
    default 256 B ≈ 205 ns at 10 Gbps) and quantized to 1/256 slot.
    """
    model: str = "cpu"              # registered HostModel implementation
    tx_cost_slots: float = 0.0      # CPU time per transmitted chunk
    tx_batch: int = 1               # chunks per interrupt/doorbell batch
    tx_batch_cost_slots: float = 0.0  # extra cost on each batch boundary
    tx_queue_cap: int = 1           # TX ring depth: idle budget accrual (chunks)
    rx_cost_slots: float = 0.0      # serialized CPU time per received chunk
    rx_queue_cap: int = 64          # RX ring depth; full -> downlink stalls

    def validate(self) -> None:
        get_host_model(self.model)          # ValueError on unknown model
        for f in ("tx_cost_slots", "tx_batch_cost_slots", "rx_cost_slots"):
            v = getattr(self, f)
            if not 0.0 <= float(v) <= 4096.0:
                raise ValueError(f"HostConfig.{f}={v!r} must be in "
                                 f"[0, 4096] slots")
        for f in ("tx_batch", "tx_queue_cap", "rx_queue_cap"):
            v = getattr(self, f)
            if not isinstance(v, int) or v < 1:
                raise ValueError(f"HostConfig.{f}={v!r} must be an int >= 1")

    # -- fixed-point views ------------------------------------------------
    @property
    def tx_cost_q(self) -> int:
        return int(round(self.tx_cost_slots * QSCALE))

    @property
    def tx_batch_cost_q(self) -> int:
        return int(round(self.tx_batch_cost_slots * QSCALE))

    @property
    def rx_cost_q(self) -> int:
        return int(round(self.rx_cost_slots * QSCALE))

    @property
    def tx_burst_q(self) -> int:
        """Token-bucket cap: ``tx_queue_cap`` chunks' worth of budget
        (never below the worst single-chunk charge, so no config can
        deadlock the gate)."""
        return max(self.tx_queue_cap * max(self.tx_cost_q, QSCALE),
                   self.tx_cost_q + self.tx_batch_cost_q)

    # -- structural gates (python-level -> compiled program identity) -----
    @property
    def tx_on(self) -> bool:
        return self.tx_cost_q > 0 or self.tx_batch_cost_q > 0

    @property
    def rx_on(self) -> bool:
        return self.rx_cost_q > 0

    @property
    def is_ideal(self) -> bool:
        """All costs zero: the host stage is structurally skipped and the
        scan is bit-identical to ``host=None`` (enforced by test)."""
        return not (self.tx_on or self.rx_on)


class HostModel(abc.ABC):
    """Enforced interface for a host/NIC stage implementation.

    ``step_fn`` talks to the host model only through these five hooks
    (the SimBricks seam: a later co-simulation backend swaps the class,
    not the scan). All hooks are pure: state in, state out; arrays only
    — they run inside ``lax.scan`` under jit/vmap/shard_map.
    """
    name: str = "base"

    @abc.abstractmethod
    def init_state(self, cfg, M: int) -> dict:
        """Per-run carry arrays (prefix ``h_``), keyed off ``cfg.host``."""

    @abc.abstractmethod
    def host_tx(self, cfg, st, want, now):
        """Gate this slot's transmissions on TX CPU availability.

        ``want``: (H,) bool — hosts with a sendable chunk selected.
        Returns ``(sent, st)``: the gated (H,) mask of hosts that may
        put their chunk on the wire this slot, and updated state
        (budget spent, deferral stats)."""

    @abc.abstractmethod
    def rx_deliver(self, cfg, st, S, now) -> dict:
        """Complete RX processing: move every ring entry whose service
        finished by ``now`` into ``st['recv']`` (at most one per host
        per slot — arrivals are at most one per host per slot, so the
        FIFO is work-conserving)."""

    @abc.abstractmethod
    def rx_room(self, cfg, st):
        """(H,) bool: hosts whose RX ring can accept a chunk this slot;
        False backpressures the downlink (the chunk stays queued)."""

    @abc.abstractmethod
    def rx_accept(self, cfg, st, S, msg, ok, now) -> dict:
        """Enqueue this slot's drained chunk (per host, masked by
        ``ok``) into the RX ring with its service-completion time."""


_HOST_MODELS: dict[str, HostModel] = {}


def register_host_model(model: HostModel) -> HostModel:
    """Register a :class:`HostModel` instance under ``model.name``.

    The abc machinery enforces the interface: a subclass missing any
    hook cannot even be instantiated."""
    if not isinstance(model, HostModel):
        raise TypeError(f"register_host_model expects a HostModel "
                        f"instance, got {type(model).__name__}")
    _HOST_MODELS[model.name] = model
    return model


def get_host_model(name: str) -> HostModel:
    try:
        return _HOST_MODELS[name]
    except KeyError:
        raise ValueError(f"unknown host model {name!r}; registered: "
                         f"{sorted(_HOST_MODELS)}") from None


class CpuHostModel(HostModel):
    """TX token bucket + bounded RX service FIFO (module docstring)."""
    name = "cpu"

    def init_state(self, cfg, M: int) -> dict:
        hc = cfg.host
        H = cfg.n_hosts
        st = {}
        if hc.tx_on:
            st.update({
                # bucket starts full: a cold host bursts its TX ring depth
                "h_tx_budget_q": jnp.full((H,), hc.tx_burst_q, I32),
                "h_tx_work_q": jnp.zeros((H,), I32),   # spent CPU micro-slots
                "h_tx_defer": jnp.zeros((H,), I32),    # slots gated w/ traffic
            })
            if hc.tx_batch > 1:
                st["h_tx_cnt"] = jnp.zeros((H,), I32)  # chunks into batch
        if hc.rx_on:
            cap = hc.rx_queue_cap
            st.update({
                "h_rx_msg": jnp.full((H, cap), -1, I32),
                "h_rx_ready_q": jnp.zeros((H, cap), I32),  # abs micro-slots
                "h_rx_head": jnp.zeros((H,), I32),
                "h_rx_tail": jnp.zeros((H,), I32),
                "h_rx_busy_q": jnp.zeros((H,), I32),   # CPU busy-until
                "h_rx_stall": jnp.zeros((H,), I32),    # slots downlink blocked
                "h_rx_q_sum": jnp.zeros((H,), jnp.float32),
                "h_rx_q_max": jnp.zeros((H,), I32),
            })
        return st

    def host_tx(self, cfg, st, want, now):
        hc = cfg.host
        budget = jnp.minimum(st["h_tx_budget_q"] + QSCALE, hc.tx_burst_q)
        charge = jnp.full_like(budget, hc.tx_cost_q)
        if hc.tx_batch > 1:
            boundary = st["h_tx_cnt"] + 1 >= hc.tx_batch
            charge = charge + jnp.where(boundary, hc.tx_batch_cost_q, 0)
        else:
            charge = charge + hc.tx_batch_cost_q
        ok = budget >= charge
        sent = want & ok
        spend = jnp.where(sent, charge, 0)
        st = {**st, "h_tx_budget_q": budget - spend,
              "h_tx_work_q": st["h_tx_work_q"] + spend,
              "h_tx_defer": st["h_tx_defer"] + (want & ~ok).astype(I32)}
        if hc.tx_batch > 1:
            st["h_tx_cnt"] = jnp.where(
                sent, jnp.where(boundary, 0, st["h_tx_cnt"] + 1),
                st["h_tx_cnt"])
        return sent, st

    def rx_deliver(self, cfg, st, S, now):
        hc = cfg.host
        H, cap = cfg.n_hosts, hc.rx_queue_cap
        M = S["size"].shape[0]
        head, tail = st["h_rx_head"], st["h_rx_tail"]
        occ = tail - head
        hh = jnp.arange(H)
        hpos = head % cap
        can = (occ > 0) & (st["h_rx_ready_q"][hh, hpos] <= now * QSCALE)
        msg = st["h_rx_msg"][hh, hpos]
        recv = st["recv"].at[jnp.where(can, msg, M)].add(
            jnp.where(can, 1, 0), mode="drop")
        return {**st, "recv": recv, "h_rx_head": head + can.astype(I32),
                "h_rx_q_sum": st["h_rx_q_sum"] + occ.astype(jnp.float32),
                "h_rx_q_max": jnp.maximum(st["h_rx_q_max"], occ)}

    def rx_room(self, cfg, st):
        return (st["h_rx_tail"] - st["h_rx_head"]) < cfg.host.rx_queue_cap

    def rx_accept(self, cfg, st, S, msg, ok, now):
        hc = cfg.host
        cap = hc.rx_queue_cap
        hh = jnp.arange(cfg.n_hosts)
        tail = st["h_rx_tail"]
        # serialized service: this chunk is processed after everything
        # already in the ring, never before its own arrival slot ends
        ready = jnp.maximum(st["h_rx_busy_q"], now * QSCALE) + hc.rx_cost_q
        col = jnp.where(ok, tail % cap, cap)                 # cap -> dropped
        return {**st,
                "h_rx_msg": st["h_rx_msg"].at[hh, col].set(msg, mode="drop"),
                "h_rx_ready_q": st["h_rx_ready_q"].at[hh, col].set(
                    ready, mode="drop"),
                "h_rx_tail": tail + ok.astype(I32),
                "h_rx_busy_q": jnp.where(ok, ready, st["h_rx_busy_q"])}


register_host_model(CpuHostModel())


HOST_PRESETS: dict[str, HostConfig] = {
    "ideal": HostConfig(),
    "kernel_stack": HostConfig(tx_cost_slots=1.0, tx_batch=8,
                               tx_batch_cost_slots=8.0, tx_queue_cap=16,
                               rx_cost_slots=2.0, rx_queue_cap=256),
    "kernel_bypass": HostConfig(tx_cost_slots=0.25, tx_queue_cap=32,
                                rx_cost_slots=0.5, rx_queue_cap=64),
}


def host_preset(name: str) -> HostConfig:
    try:
        return HOST_PRESETS[name]
    except KeyError:
        raise ValueError(f"unknown host preset {name!r}; available: "
                         f"{sorted(HOST_PRESETS)}") from None


def as_host_config(host) -> HostConfig | None:
    """Normalize ``SimConfig.host``: HostConfig | preset name | dict | None."""
    if host is None or isinstance(host, HostConfig):
        return host
    if isinstance(host, str):
        return host_preset(host)
    if isinstance(host, dict):
        return HostConfig(**host)
    raise TypeError(f"SimConfig.host must be a HostConfig, preset name, "
                    f"dict, or None — got {type(host).__name__}")


__all__ = ["HostConfig", "HostModel", "CpuHostModel", "HOST_PRESETS",
           "host_preset", "as_host_config", "register_host_model",
           "get_host_model", "QSCALE"]
