"""Transport protocols as composable policies (DESIGN.md §1).

The paper decomposes receiver-driven transport into independent policies:
grant scheduling (§3.3), priority allocation (§3.4), and controlled
overcommitment (§3.5). This module mirrors that decomposition so
``sim.step_fn`` stays policy-agnostic orchestration of uplinks, network
delay, and downlink priority queues:

  ``SenderPolicy``    which message each host transmits next (chunk
                      selection order) and the priority stamped on the
                      outgoing chunk — honoured by every queueing tier
                      the chunk crosses (TOR uplinks under a leaf-spine
                      ``FabricConfig``, and the receiver downlink).
  ``ReceiverPolicy``  which messages are granted this slot, the scheduled
                      priority assigned to each, and the overcommitment
                      degree (how many senders are granted concurrently).
  ``Protocol``        one named sender+receiver pair plus per-message
                      static preparation (unscheduled window + priority)
                      and optional per-slot hooks (drain bookkeeping,
                      timeout handling).

All policy objects are frozen dataclasses: hashable, comparable, and
therefore usable as static arguments to ``jax.jit`` — a protocol choice is
compile-time structure, not runtime data.

The six paper protocols (homa, basic, phost, pias, pfabric, ndp) are
registered here; their approximations are documented in DESIGN.md §3.
Register new variants with :func:`register`; :func:`get_protocol` raises
``ValueError`` naming the registry on an unknown name.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.kernels.arbiter.dispatch import topk as backend_topk

I32 = jnp.int32
BIG = jnp.int32(2 ** 30)
MSG_BITS = 13
MSG_MOD = 1 << MSG_BITS          # max messages per sim
ORDER_CAP = (1 << 17) - 1        # sender-order keys clamp here


# --------------------------------------------------------------- senders ---

@dataclasses.dataclass(frozen=True)
class SenderPolicy:
    """Chunk selection order + priority stamping at the sending host."""

    def order(self, cfg, st, S, now, remaining):
        """(M,) int32 key; per host, the sendable message with the smallest
        key transmits this slot (ties break toward the smallest msg id)."""
        raise NotImplementedError

    def chunk_prio(self, cfg, st, S, cm, unsched, n_sched):
        """(H,) int32 wire priority for each host's chosen chunk
        (smaller = served first). This is the priority stamped in the
        packet header, so EVERY queueing tier honours it: the receiver
        downlink always, and — when ``cfg.fabric`` models a leaf-spine
        network — the TOR uplink queues too (DESIGN.md §5). ``cm`` is
        the chosen message per host (clamped), ``unsched`` marks chunks
        inside the blind window."""
        raise NotImplementedError

    def on_send(self, cfg, st, S, cm, has, now):
        """Post-transmit bookkeeping hook (default: none) — policies that
        need per-send state (e.g. fair-share ordering) update it here, so
        other protocols don't pay the scatter."""
        return st


@dataclasses.dataclass(frozen=True)
class SrptSender(SenderPolicy):
    """Shortest-remaining-processing-time chunk order (paper §3.2)."""

    def order(self, cfg, st, S, now, remaining):
        return jnp.minimum(remaining, ORDER_CAP)


@dataclasses.dataclass(frozen=True)
class FifoSender(SenderPolicy):
    """Arrival-order senders (NDP's per-message FIFO pull queues)."""

    def order(self, cfg, st, S, now, remaining):
        return jnp.minimum(S["arrival"], ORDER_CAP)


@dataclasses.dataclass(frozen=True)
class FairShareSender(SenderPolicy):
    """Least-recently-served round robin (DCTCP-style fair sharing)."""

    def order(self, cfg, st, S, now, remaining):
        return jnp.minimum(st["last_sent"], ORDER_CAP)

    def on_send(self, cfg, st, S, cm, has, now):
        last_sent = st["last_sent"].at[cm].set(
            jnp.where(has, now, st["last_sent"][cm]), mode="drop")
        return {**st, "last_sent": last_sent}


# ------------------------------------------------------------- receivers ---

@dataclasses.dataclass(frozen=True)
class ReceiverPolicy:
    """Grant issue + scheduled-priority assignment + overcommit degree."""

    def grants(self, cfg, st, S, now, n_sched, topk=None):
        """Returns ``(grant_r, sched_prio, active, withheld)``:
        (M,) granted slots, (M,) scheduled priority, (M,) bool mask of
        messages the receivers actively schedule, and (H,) bool — hosts
        with known-but-ungranted traffic (wasted-bandwidth accounting).

        ``topk`` is the precomputed ``(vals, idx)`` answer to this
        policy's :meth:`grant_problem` — supplied by the ``pallas_fused``
        backend, which solves it inside the fused per-slot kernel
        (DESIGN.md §11). Policies without a grant problem ignore it."""
        raise NotImplementedError

    def grant_problem(self, cfg, st, S, now, n_sched):
        """The top-K selection this policy would issue this slot, as
        ``(keys (H, M), K)`` for the fused kernel — or ``None`` if the
        policy selects no grant set (window receivers). Must read exactly
        the state :meth:`grants` reads, so solving it at slot start is
        bit-identical to solving it inside :meth:`grants`."""
        return None

    def resend(self, cfg, st, S, now, known, quiet):
        """Receiver-side loss detection (paper §3.7): (M,) bool mask of
        messages whose sender should rewind to the receiver's high-water
        mark this slot. ``known`` marks messages the receiver has heard
        from (recv > 0); ``quiet`` is slots since the last chunk arrival
        (or rewind). Only called on fault-enabled fabrics; the default
        leaves recovery entirely to the sender fallback timeout — the
        honest model for window baselines with no receiver scheduler."""
        return jnp.zeros_like(known)


def window_grants(cfg, st, S, gate):
    """Shared helper: keep ``gate``-ed messages granted one RTT of data
    beyond what was received (classic receive-window clocking)."""
    grant_r = jnp.where(gate,
                        jnp.minimum(S["size"], st["recv"] + cfg.rtt_slots),
                        st["grant_r"])
    grant_r = jnp.maximum(grant_r, st["grant_r"])
    no_withheld = jnp.zeros((cfg.n_hosts,), bool)
    return grant_r, jnp.zeros_like(st["sched_prio"]), gate, no_withheld


def srpt_grant_matrix(cfg, st, S, eligible, K):
    """The receiver-side SRPT selection problem as a dense key matrix:
    ``(keys (H, M), K)`` where row h holds the grant key of every message
    destined to host h (0 = ineligible) and K is clamped to M. This is
    the ``(mat, K)`` that :func:`topk_srpt_grants` selects over — split
    out so the ``pallas_fused`` backend can pose the identical problem
    to the fused kernel at slot start (``ReceiverPolicy.grant_problem``).

    The key orders by (remaining, msg): smaller remaining wins, ties
    break toward the SMALLEST msg id. A stable active set is what gives
    SRPT its run-to-completion behaviour — an unstable tie-break churns
    the active message and leaks grants to every tied message
    (catastrophic under incast, where all messages are the same size)."""
    size, dst_oh = S["size"], S["dst_onehot"]
    remaining = jnp.maximum(size - st["recv"], 0)
    K = min(K, size.shape[0])        # can't select more than M messages
    keyval = ((jnp.int32(1 << 17) - jnp.minimum(remaining, (1 << 17) - 1))
              << MSG_BITS) | (MSG_MOD - 1 - S["msg_ids"])
    mat = jnp.where(dst_oh & eligible[None, :], keyval[None, :], 0)  # (H, M)
    return mat, K


def topk_srpt_grants(cfg, st, S, eligible, K, n_sched, topk=None):
    """Shared helper: each receiver grants its top-K SRPT messages one RTT
    ahead and assigns scheduled priorities lowest-levels-first (paper
    §3.4/Fig. 5), shortest message on the highest scheduled level. The
    top-K selection is backend-dispatched (``SimConfig.backend``,
    DESIGN.md §6): the pallas path runs the ``srpt_topk`` kernel, whose
    index output IS the winning message id (columns of ``mat``), so no
    key-decoding or re-matching scan is needed on either backend. The
    ``pallas_fused`` backend passes the selection in pre-solved
    (``topk=(vals, idx)``, from the fused slot kernel — DESIGN.md §11)."""
    size, dst_oh = S["size"], S["dst_onehot"]
    if topk is None:
        mat, K = srpt_grant_matrix(cfg, st, S, eligible, K)
        vals, idx = backend_topk(mat, K, backend=cfg.backend,
                                 interpret=cfg.pallas_interpret)     # (H, K)
    else:
        vals, idx = topk
        K = vals.shape[1]
    valid = vals > 0
    msgs = jnp.where(valid, idx, MSG_MOD)                            # sentinel
    n_active = valid.sum(axis=1)                                     # (H,)
    # scheduled priority: rank r (0 = fewest remaining) among A active gets
    # level (A-1-r): lowest levels used first, shortest on top (paper §3.4)
    ranks = jnp.arange(K)[None, :]
    prio = jnp.clip(n_active[:, None] - 1 - ranks, 0, max(n_sched - 1, 0))

    flat_msgs = msgs.reshape(-1)
    new_grant = jnp.minimum(size, st["recv"] + cfg.rtt_slots)
    grant_r = st["grant_r"]
    grant_r = grant_r.at[flat_msgs].max(
        jnp.where(valid.reshape(-1), new_grant[
            jnp.minimum(flat_msgs, len(size) - 1)], 0), mode="drop")
    sched_prio = st["sched_prio"].at[flat_msgs].set(
        prio.reshape(-1), mode="drop")

    active = jnp.zeros_like(eligible).at[flat_msgs].set(
        valid.reshape(-1), mode="drop")
    withheld = (dst_oh & eligible[None, :] & ~active[None, :]).any(axis=1)
    return grant_r, sched_prio, active, withheld


def grant_preempted(prev_active, active, completion):
    """(M,) bool: messages evicted from the receiver's active grant set
    this slot while still incomplete — i.e. preempted for better (shorter)
    messages under SRPT overcommitment (paper §3.5), not retired by
    completion. Used by the telemetry event ledger."""
    return prev_active & ~active & (completion < 0)


@dataclasses.dataclass(frozen=True)
class WindowReceiver(ReceiverPolicy):
    """RTT-window grants to every known (``blind=False``) or merely arrived
    (``blind=True``) incomplete message; no receiver-side scheduling."""
    blind: bool = False

    def grants(self, cfg, st, S, now, n_sched, topk=None):
        if self.blind:
            gate = (S["arrival"] <= now) & (st["completion"] < 0)
        else:
            gate = (st["recv"] > 0) & (st["completion"] < 0)
        return window_grants(cfg, st, S, gate)


@dataclasses.dataclass(frozen=True)
class OvercommitSrptReceiver(ReceiverPolicy):
    """Homa's receiver: top-K SRPT with controlled overcommitment
    (paper §3.5). K defaults to the number of scheduled priority levels;
    ``cfg.overcommit`` overrides it. ``max_k=1`` models single-grant
    receivers (pHost); ``stall_aware`` honours the sender-timeout
    blacklist maintained by :class:`Phost.post_step`."""
    max_k: int | None = None
    stall_aware: bool = False

    def _k(self, cfg, n_sched):
        if self.max_k is not None:
            return self.max_k
        return cfg.overcommit or max(n_sched, 1)

    def _eligible(self, cfg, st, now):
        eligible = (st["recv"] > 0) & (st["completion"] < 0)
        if self.stall_aware:
            eligible = eligible & (st["stall_until"] <= now)
        return eligible

    def grants(self, cfg, st, S, now, n_sched, topk=None):
        eligible = self._eligible(cfg, st, now)
        return topk_srpt_grants(cfg, st, S, eligible,
                                self._k(cfg, n_sched), n_sched, topk=topk)

    def grant_problem(self, cfg, st, S, now, n_sched):
        return srpt_grant_matrix(cfg, st, S, self._eligible(cfg, st, now),
                                 self._k(cfg, n_sched))

    def resend(self, cfg, st, S, now, known, quiet):
        # Homa's receiver timeout (paper §3.7): a receiver that actively
        # schedules its inbound messages RESENDs any known message that
        # has gone quiet for ~2 RTT — much faster than the sender
        # fallback, which is the point of receiver-driven recovery.
        return known & (quiet >= cfg.fabric.faults.resend_slots)


# ------------------------------------------------------------- protocols ---

@dataclasses.dataclass(frozen=True)
class Protocol:
    """One named transport protocol = sender policy + receiver policy +
    static per-message preparation + optional per-slot hooks."""
    name: str = ""
    sender: SenderPolicy = dataclasses.field(default_factory=SrptSender)
    receiver: ReceiverPolicy = dataclasses.field(
        default_factory=WindowReceiver)

    # ---- static preparation (numpy, once per table) ----

    def unsched_limit(self, cfg, M, unsched_limit_bytes):
        """Per-message unscheduled (blind) byte budget."""
        if unsched_limit_bytes is None:
            unsched_limit_bytes = cfg.rtt_bytes
        return np.broadcast_to(np.asarray(unsched_limit_bytes), (M,))

    def unsched_prio(self, cfg, sizes, alloc):
        """Per-message priority level for unscheduled chunks."""
        return np.zeros((len(sizes),))

    def n_sched(self, cfg, alloc):
        """Number of scheduled priority levels (static scan parameter)."""
        return max(cfg.overcommit or alloc.n_sched, 1)

    def extra_state(self, cfg, M):
        """Protocol-private scan state, merged into the carry — only the
        protocols that need an array pay for hauling it."""
        return {}

    # ---- per-slot hooks (traced) ----

    def on_drain(self, cfg, st, S, drained_msg, any_elig, now):
        """Called after the downlink drains a chunk; returns updated state."""
        return st

    def post_step(self, cfg, st, S, now, active, drained_msg, any_elig):
        """End-of-slot hook (e.g. timeout bookkeeping); returns state."""
        return st


@dataclasses.dataclass(frozen=True)
class ConstPrioSender(SrptSender):
    """SRPT order, all chunks on one fixed priority level."""
    level: int = 0

    def chunk_prio(self, cfg, st, S, cm, unsched, n_sched):
        return jnp.full_like(cm, self.level)


@dataclasses.dataclass(frozen=True)
class NdpSender(FifoSender):
    """FIFO order; unscheduled chunks above scheduled, two static levels."""

    def chunk_prio(self, cfg, st, S, cm, unsched, n_sched):
        return jnp.where(unsched, 0, 1).astype(I32)


@dataclasses.dataclass(frozen=True)
class HomaSender(SrptSender):
    """Receiver-allocated priorities (paper §3.4): unscheduled levels from
    the workload CDF, scheduled levels from the grant's priority field."""

    def chunk_prio(self, cfg, st, S, cm, unsched, n_sched):
        up = (cfg.n_prios - 1 - S["uprio"][cm])      # inverted: smaller=better
        sp = (n_sched - 1 - st["sched_prio"][cm])    # within scheduled band
        sched_inv = (cfg.n_prios - n_sched) + sp     # scheduled below unsched
        # unscheduled levels sit above (smaller inv value) all scheduled
        return jnp.where(unsched, up, sched_inv).astype(I32)


@dataclasses.dataclass(frozen=True)
class Homa(Protocol):
    name: str = "homa"
    sender: SenderPolicy = dataclasses.field(default_factory=HomaSender)
    receiver: ReceiverPolicy = dataclasses.field(
        default_factory=OvercommitSrptReceiver)

    def unsched_prio(self, cfg, sizes, alloc):
        return alloc.unsched_prio(sizes)

    def n_sched(self, cfg, alloc):
        return max(alloc.n_sched, 1)


@dataclasses.dataclass(frozen=True)
class Basic(Protocol):
    """Receiver-window transport with no priorities (the paper's 'basic'
    receiver-driven baseline)."""
    name: str = "basic"
    sender: SenderPolicy = dataclasses.field(default_factory=ConstPrioSender)
    receiver: ReceiverPolicy = dataclasses.field(
        default_factory=WindowReceiver)


@dataclasses.dataclass(frozen=True)
class PhostTwoLevelSender(SrptSender):
    """SRPT order; RTS/unscheduled packets above scheduled data."""

    def chunk_prio(self, cfg, st, S, cm, unsched, n_sched):
        return jnp.where(unsched, 0, 1).astype(I32)


@dataclasses.dataclass(frozen=True)
class Phost(Protocol):
    """pHost: single-message grants (token per RTT, K=1) with a sender
    timeout that blacklists unresponsive messages (DESIGN.md §3)."""
    name: str = "phost"
    sender: SenderPolicy = dataclasses.field(
        default_factory=PhostTwoLevelSender)
    receiver: ReceiverPolicy = dataclasses.field(
        default_factory=lambda: OvercommitSrptReceiver(max_k=1,
                                                       stall_aware=True))

    def unsched_prio(self, cfg, sizes, alloc):
        return np.full((len(sizes),), cfg.n_prios - 1)

    def extra_state(self, cfg, M):
        return {"stall_until": jnp.zeros((M,), I32),   # timeout blacklist
                "last_progress": jnp.zeros((M,), I32)}

    def post_step(self, cfg, st, S, now, active, drained_msg, any_elig):
        # if the single granted message makes no progress for `timeout`
        # slots, blacklist it briefly so the receiver switches to another
        # message (approximates pHost's sender-timeout mechanism).
        M = S["size"].shape[0]
        lp = st["last_progress"]
        lp = jnp.maximum(lp, S["arrival"])            # clock starts at arrival
        lp = lp.at[jnp.minimum(drained_msg, M - 1)].max(
            jnp.where(any_elig, now, 0), mode="drop")
        timed_out = active & (st["grant_r"] > st["recv"]) & \
            (now - lp > cfg.phost_timeout_slots)
        new_stall = jnp.where(timed_out, now + cfg.phost_timeout_slots,
                              st["stall_until"])
        return {**st, "stall_until": new_stall, "last_progress": lp}


@dataclasses.dataclass(frozen=True)
class PiasSender(FairShareSender):
    """MLFQ: chunks demote to lower levels as the flow's sent bytes cross
    the precomputed thresholds (level 0 first, demoted upward)."""

    def chunk_prio(self, cfg, st, S, cm, unsched, n_sched):
        sent = st["sent"][cm]
        lvl = jnp.searchsorted(S["pias_cuts"], sent, side="right")
        return lvl.astype(I32)


@dataclasses.dataclass(frozen=True)
class Pias(Protocol):
    name: str = "pias"
    sender: SenderPolicy = dataclasses.field(default_factory=PiasSender)

    def extra_state(self, cfg, M):
        return {"last_sent": jnp.zeros((M,), I32)}     # round-robin clock

    receiver: ReceiverPolicy = dataclasses.field(
        default_factory=lambda: WindowReceiver(blind=True))

    def unsched_limit(self, cfg, M, unsched_limit_bytes):
        return np.full((M,), cfg.rtt_bytes)          # blind first window


@dataclasses.dataclass(frozen=True)
class PfabricSender(SrptSender):
    """Continuous priority = remaining slots (pFabric's ideal SRPT wire)."""

    def chunk_prio(self, cfg, st, S, cm, unsched, n_sched):
        return jnp.maximum(S["size"][cm] - st["sent"][cm], 0)


@dataclasses.dataclass(frozen=True)
class Pfabric(Protocol):
    name: str = "pfabric"
    sender: SenderPolicy = dataclasses.field(default_factory=PfabricSender)
    receiver: ReceiverPolicy = dataclasses.field(
        default_factory=lambda: WindowReceiver(blind=True))

    def unsched_limit(self, cfg, M, unsched_limit_bytes):
        return np.full((M,), cfg.rtt_bytes)          # blind first window


@dataclasses.dataclass(frozen=True)
class Ndp(Protocol):
    """NDP: FIFO pull queues per receiver, two static priority levels
    (header/retransmit above bulk), per-message round-robin service."""
    name: str = "ndp"
    sender: SenderPolicy = dataclasses.field(default_factory=NdpSender)
    receiver: ReceiverPolicy = dataclasses.field(
        default_factory=WindowReceiver)

    def unsched_prio(self, cfg, sizes, alloc):
        return np.full((len(sizes),), cfg.n_prios - 1)

    def extra_state(self, cfg, M):
        return {"last_served": jnp.zeros((M,), I32)}   # fair-share clock

    def on_drain(self, cfg, st, S, drained_msg, any_elig, now):
        # fair-share bookkeeping: round-robin via last-served ordering
        M = S["size"].shape[0]
        ls = st["last_served"].at[jnp.minimum(drained_msg, M - 1)].set(
            now, mode="drop")
        return {**st, "last_served": ls}


# --------------------------------------------------------------- registry ---

_REGISTRY: dict[str, Protocol] = {}


def register(proto: Protocol) -> Protocol:
    """Register a protocol under ``proto.name`` (overwrites silently so a
    variant can shadow a builtin during experiments)."""
    if not proto.name:
        raise ValueError("protocol needs a non-empty name")
    _REGISTRY[proto.name] = proto
    return proto


def registered_protocols() -> list[str]:
    return sorted(_REGISTRY)


def get_protocol(name: str) -> Protocol:
    """Look up a registered protocol; unknown names raise ``ValueError``
    listing what is available (satellite: no silent fall-through)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown protocol {name!r}; registered protocols: "
            f"{registered_protocols()}") from None


for _p in (Homa(), Basic(), Phost(), Pias(), Pfabric(), Ndp()):
    register(_p)
