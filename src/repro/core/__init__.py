# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
from repro.core.sim import (SimConfig, SimResult, simulate, run_sweep,
                            slowdown_percentiles)
from repro.core.sweep import SweepSpec, StreamSpec, SweepStats
from repro.core.fabric import FabricConfig
from repro.core.faults import FaultConfig
from repro.core.hostmodel import (HostConfig, HostModel, host_preset,
                                  register_host_model)
from repro.core.telemetry import TraceConfig, SimTrace
from repro.core.protocols import (Protocol, SenderPolicy, ReceiverPolicy,
                                  register, get_protocol,
                                  registered_protocols)
from repro.core.workloads import MessageTable, WorkloadSpec, make_messages
from repro.core import scenarios
from repro.core.priorities import PriorityAllocation, allocate_priorities

__all__ = [
    "SimConfig", "SimResult", "FabricConfig", "FaultConfig", "TraceConfig",
    "SimTrace", "HostConfig", "HostModel", "host_preset",
    "register_host_model", "simulate",
    "run_sweep", "SweepSpec", "StreamSpec", "SweepStats",
    "slowdown_percentiles",
    "Protocol", "SenderPolicy", "ReceiverPolicy", "register",
    "get_protocol", "registered_protocols",
    "MessageTable", "WorkloadSpec", "make_messages", "scenarios",
    "PriorityAllocation", "allocate_priorities",
]
