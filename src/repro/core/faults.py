"""Fault injection, loss recovery, and spine routing (DESIGN.md §7).

The paper's loss-recovery machinery (§3.7: receivers detect missing data
and request RESENDs; senders retransmit) only matters on a fabric that
can actually lose packets.  This module makes the leaf-spine tier lossy
and failure-prone, and gives every registered protocol a way to survive
it, as three composable pieces configured on :class:`FabricConfig`:

**1. Loss & failure injection** (:class:`FaultConfig`)
  - Bernoulli per-chunk loss on the TOR uplinks (``up_loss``, the
    host→spine leg) and at the destination downlink enqueue
    (``down_loss``, the last-hop leg — also covers intra-rack chunks,
    so ``racks=1`` gives a lossy single switch).
  - Gilbert–Elliott burst loss per uplink: a two-state Markov chain
    (good↔bad, transition probabilities ``ge_p_gb``/``ge_p_bg`` per
    slot) adds ``ge_loss`` to the drop probability while the link is in
    the bad state — loss arrives in bursts, the regime that defeats
    naive FEC and stresses timeout-based recovery.
  - Scheduled failure windows: ``link_fail=((uplink, start, end), ...)``
    takes one TOR uplink down for ``[start, end)``; ``tor_fail=((rack,
    start, end), ...)`` takes a whole TOR down — its uplinks drop
    everything, its hosts' downlinks neither accept nor drain chunks,
    and chunks transmitted *by* the rack's hosts die at the dead TOR.

  All randomness is a counter-based integer hash of ``(link, slot,
  seed)`` — no PRNG state threads the scan, draws are independent
  across retransmission rounds, and runs are bit-reproducible on both
  compute backends and under ``run_sweep``'s vmap.

**2. Loss recovery** (:func:`apply_recovery` + the
:meth:`ReceiverPolicy.resend <repro.core.protocols.ReceiverPolicy>`
hook)
  Chunks in this simulator are fungible slots of a message, so "sender
  retransmits lost packet" becomes "sender rewinds its send offset to
  what the receiver has": a RESEND rewinds ``sent`` to ``recv`` and
  credits the difference to a per-message ``retx`` counter (so chunk
  conservation — transmissions = ``sent + retx`` — still balances).
  Two timers drive it, both keyed off the last slot a chunk of the
  message arrived (or the last rewind — retransmissions get a full
  quiet period before firing again):

  - *receiver RESEND* (paper §3.7): receivers that actively schedule
    (Homa's and pHost's ``OvercommitSrptReceiver``) resend-poll any
    *known* incomplete message quiet for ``resend_slots``.
  - *sender fallback timeout*: every protocol rewinds a quiet message
    after ``sender_timeout_slots`` (≫ ``resend_slots``), covering the
    window-receiver baselines and the case where every unscheduled
    chunk was lost and the receiver never learned of the message.

  A rewind can race chunks still queued in the fabric; those arrive as
  duplicates, which in the fungible-chunk model are just wasted
  bandwidth (counted — they inflate ``retx``), never corruption.

**3. Spine routing** (``FabricConfig.routing``)
  - ``"ecmp"`` — today's behavior, untouched: a static per-message hash
    (computed in ``prepare``) that is oblivious to failures, so chunks
    keep dying on a failed uplink until its window ends.
  - ``"flowlet"`` — the per-message hash is re-salted with a time epoch
    (``now // flowlet_slots``), so a flow pinned to a dead or congested
    uplink escapes at the next epoch boundary.
  - ``"adaptive"`` — per-slot least-loaded selection: each rack routes
    this slot's cross-rack chunks to its uplink with the smallest queue
    occupancy, with failed uplinks masked out — reacts immediately to
    both congestion and failures.

``FabricConfig.faults=None`` (the default) keeps the scan free of every
array and op defined here: the zero-fault program is bit-identical to
the pre-fault simulator (pinned by the fabric goldens on both
backends).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.protocols import BIG, I32

_U32 = jnp.uint32


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Loss/failure/recovery parameters (hashable: rides the jit-static
    :class:`FabricConfig`). All probabilities are per chunk per slot."""
    up_loss: float = 0.0            # Bernoulli loss at TOR uplink enqueue
    down_loss: float = 0.0          # Bernoulli loss at downlink enqueue
    ge_p_gb: float = 0.0            # Gilbert-Elliott good->bad per slot
    ge_p_bg: float = 0.05           # Gilbert-Elliott bad->good per slot
    ge_loss: float = 0.5            # extra uplink loss while in bad state
    # scheduled failure windows, half-open [start, end) in slots:
    link_fail: tuple[tuple[int, int, int], ...] = ()   # (uplink, s, e)
    tor_fail: tuple[tuple[int, int, int], ...] = ()    # (rack, s, e)
    # recovery timers (slots of quiet before firing; see module doc).
    # Deliberately conservative — many RTTs, like real Homa's resend
    # ticker: an oversubscribed uplink queue can delay a chunk for
    # hundreds of slots, and a timer shorter than that mistakes
    # queueing for loss and rewinds in-flight data, a duplicate storm
    # that amplifies the very congestion that triggered it.
    resend_slots: int = 300          # receiver RESEND (~8 RTT)
    sender_timeout_slots: int = 760  # sender fallback (~20 RTT)
    seed: int = 0                   # loss-draw hash seed

    def __post_init__(self):
        # normalize JSON-deserialized lists into hashable tuples
        object.__setattr__(self, "link_fail", tuple(
            tuple(int(v) for v in w) for w in self.link_fail))
        object.__setattr__(self, "tor_fail", tuple(
            tuple(int(v) for v in w) for w in self.tor_fail))

    @property
    def ge_on(self) -> bool:
        return self.ge_p_gb > 0

    @property
    def any_loss(self) -> bool:
        return (self.up_loss > 0 or self.down_loss > 0 or self.ge_on
                or bool(self.link_fail) or bool(self.tor_fail))

    def validate(self, fab, n_hosts: int) -> None:
        for name in ("up_loss", "down_loss", "ge_loss"):
            p = getattr(self, name)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"FaultConfig.{name} must be a "
                                 f"probability in [0, 1], got {p}")
        if not 0.0 <= self.ge_p_gb <= 1.0 or not 0.0 <= self.ge_p_bg <= 1.0:
            raise ValueError("FaultConfig.ge_p_gb/ge_p_bg must be "
                             "probabilities in [0, 1]")
        if self.ge_on and self.ge_p_bg <= 0:
            raise ValueError(
                "FaultConfig.ge_p_bg must be > 0 when ge_p_gb > 0: a "
                "bad link that can never recover black-holes its spine "
                "forever (use a link_fail window for permanent failure)")
        if self.resend_slots < 1 or self.sender_timeout_slots < 1:
            raise ValueError("FaultConfig recovery timeouts must be >= 1 "
                             "slot")
        U = fab.n_uplinks_total(n_hosts)
        for w in self.link_fail:
            if len(w) != 3 or not (0 <= w[0] < U) or w[1] < 0 \
                    or w[2] <= w[1]:
                raise ValueError(
                    f"FaultConfig.link_fail window {w!r} must be "
                    f"(uplink in [0, {U}), start >= 0, end > start)")
        for w in self.tor_fail:
            if len(w) != 3 or not (0 <= w[0] < fab.racks) or w[1] < 0 \
                    or w[2] <= w[1]:
                raise ValueError(
                    f"FaultConfig.tor_fail window {w!r} must be "
                    f"(rack in [0, {fab.racks}), start >= 0, end > start)")


# ------------------------------------------------ counter-based hashing ----
# One uniform draw per (row, slot): an xorshift-multiply mix, the in-scan
# (jnp, traced-``now``) sibling of ``fabric.spine_hash``. Distinct draw
# sites mix a distinct salt into the seed so co-indexed draws (e.g. the
# per-uplink GE transition and the per-uplink forward-loss draw in the
# same slot) stay independent.

_SALT_CHUNK = 0x1B56C4E9     # per-host transmit-chunk loss draw
_SALT_GE = 0x60BEE0D1        # per-uplink Gilbert-Elliott transition
_SALT_FWD = 0x7FEB352D       # per-uplink spine->downlink loss draw
_SALT_FLOWLET = 0x46D9F3B3   # flowlet epoch re-hash


def _hash_u32(a, b, seed: int, salt: int):
    h = (jnp.asarray(a).astype(_U32) * _U32(0x9E3779B1)
         ^ jnp.asarray(b).astype(_U32) * _U32(0x85EBCA77)
         ^ _U32(((seed * 0x27D4EB2F) ^ salt) & 0xFFFFFFFF))
    h ^= h >> _U32(15)
    h *= _U32(0x2C1B3C6D)
    h ^= h >> _U32(13)
    h *= _U32(0x297A2D39)
    h ^= h >> _U32(16)
    return h


def _uniform01(a, b, seed: int, salt: int):
    """Deterministic uniforms in [0, 1) keyed by (a, b, seed, salt)."""
    return _hash_u32(a, b, seed, salt).astype(jnp.float32) \
        * jnp.float32(2.0 ** -32)


# ---------------------------------------------------- failure windows ------

def link_down_mask(cfg, now):
    """(U,) bool: uplinks inside a ``link_fail`` window or belonging to a
    TOR inside a ``tor_fail`` window."""
    fab = cfg.fabric
    fl = fab.faults
    U = fab.n_uplinks_total(cfg.n_hosts)
    n_up = fab.n_uplinks(cfg.n_hosts)
    rows = jnp.arange(U, dtype=I32)
    down = jnp.zeros((U,), bool)
    for (u, s, e) in fl.link_fail:
        down |= (rows == u) & (now >= s) & (now < e)
    for (r, s, e) in fl.tor_fail:
        down |= (rows // n_up == r) & (now >= s) & (now < e)
    return down


def host_down_mask(cfg, now):
    """(H,) bool: hosts whose TOR is inside a ``tor_fail`` window — their
    downlinks neither accept nor drain chunks, and chunks they transmit
    die at the dead TOR."""
    fab = cfg.fabric
    fl = fab.faults
    H = cfg.n_hosts
    rs = fab.rack_size(H)
    hosts = jnp.arange(H, dtype=I32)
    down = jnp.zeros((H,), bool)
    for (r, s, e) in fl.tor_fail:
        down |= (hosts // rs == r) & (now >= s) & (now < e)
    return down


# ------------------------------------------------------- scan state --------

def init_fault_state(cfg, M: int) -> dict:
    """Fault/recovery scan state; only fault-enabled configs carry it."""
    U = cfg.fabric.n_uplinks_total(cfg.n_hosts)
    z = lambda shape: jnp.zeros(shape, I32)  # noqa: E731
    return {
        "retx": z((M,)),                    # chunks re-credited by rewinds
        "msg_lost": z((M,)),                # fault-dropped chunks per msg
        "first_loss": jnp.full((M,), BIG, I32),
        "last_arr": z((M,)),                # last slot a chunk drained
        "last_rw": z((M,)),                 # last rewind slot (backoff)
        "f_lost": z(()),                    # total fault-dropped chunks
        "ge_bad": jnp.zeros((U,), bool),    # Gilbert-Elliott link state
    }


def _record_drops(st, cm, dropped, now):
    """Account fault drops: per-message counts, first-loss slot, total."""
    return {**st,
            "msg_lost": st["msg_lost"].at[cm].add(
                dropped.astype(I32), mode="drop"),
            "first_loss": st["first_loss"].at[cm].min(
                jnp.where(dropped, now, BIG), mode="drop"),
            "f_lost": st["f_lost"] + dropped.sum()}


# -------------------------------------------------------- loss points ------

def inject_losses(cfg, st, cm, local, remote, dsts, urow, now):
    """Apply the transmit-side loss points to this slot's chunks: link /
    TOR failure drops, Bernoulli uplink + downlink loss, and
    Gilbert-Elliott burst loss on the chosen uplink. ``local`` /
    ``remote`` are the per-host insert masks from ``route_chunks``;
    returns the thinned masks plus updated state."""
    fl = cfg.fabric.faults
    H = cfg.n_hosts
    hosts = jnp.arange(H, dtype=I32)
    dstc = jnp.minimum(dsts, H - 1)

    st = advance_ge(cfg, st, now)
    host_down = host_down_mask(cfg, now)
    link_down = link_down_mask(cfg, now)

    u = _uniform01(hosts, now, fl.seed, _SALT_CHUNK)
    p_up = jnp.float32(fl.up_loss)
    if fl.ge_on:
        p_up = p_up + jnp.where(st["ge_bad"][urow],
                                jnp.float32(fl.ge_loss), 0.0)
    drop_local = local & (host_down[hosts] | host_down[dstc]
                          | (u < fl.down_loss))
    drop_remote = remote & (host_down[hosts] | link_down[urow]
                            | (u < p_up))
    dropped = drop_local | drop_remote
    st = _record_drops(st, cm, dropped, now)
    return local & ~drop_local, remote & ~drop_remote, st


def advance_ge(cfg, st, now):
    """One Gilbert-Elliott transition per uplink per slot (no-op unless
    the chain is enabled)."""
    fl = cfg.fabric.faults
    if not fl.ge_on:
        return st
    U = st["ge_bad"].shape[0]
    ug = _uniform01(jnp.arange(U, dtype=I32), now, fl.seed, _SALT_GE)
    bad = st["ge_bad"]
    return {**st, "ge_bad": jnp.where(bad, ug >= fl.ge_p_bg,
                                      ug < fl.ge_p_gb)}


def forward_losses(cfg, st, msg, dst, any_e, now):
    """Loss point for chunks leaving an uplink toward the destination
    downlink (the spine→TOR→host leg): ``down_loss`` Bernoulli drops
    plus dead-destination drops. Returns the thinned insert mask."""
    fl = cfg.fabric.faults
    H = cfg.n_hosts
    U = dst.shape[0]
    host_down = host_down_mask(cfg, now)
    uf = _uniform01(jnp.arange(U, dtype=I32), now, fl.seed, _SALT_FWD)
    dropf = any_e & (host_down[jnp.minimum(dst, H - 1)]
                     | (uf < fl.down_loss))
    st = _record_drops(st, msg, dropf, now)
    return any_e & ~dropf, st


# ----------------------------------------------------- spine routing -------

def select_uplink(cfg, st, S, cm, src_rack, now):
    """(H,) absolute uplink row for each host's chosen chunk under the
    non-ECMP routing policies (``route_chunks`` keeps the static ECMP
    path inline so the default program is untouched)."""
    fab = cfg.fabric
    n_up = fab.n_uplinks(cfg.n_hosts)
    if fab.routing == "flowlet":
        # per-message hash re-salted every flowlet_slots: a flow pinned
        # to a dead or congested spine escapes at the epoch boundary
        epoch = now // fab.flowlet_slots
        spine = (_hash_u32(cm, epoch, fab.seed, _SALT_FLOWLET)
                 % _U32(n_up)).astype(I32)
    elif fab.routing == "adaptive":
        # least-loaded uplink of the sender's rack this slot; failed
        # uplinks are masked out so routing reacts to failures at once
        occ = st["u_valid"].sum(axis=1).astype(I32)
        if fab.faults is not None:
            occ = jnp.where(link_down_mask(cfg, now), BIG, occ)
        best = jnp.argmin(occ.reshape(fab.racks, n_up), axis=1) \
            .astype(I32)                        # ties -> lowest uplink
        spine = best[src_rack]
    else:  # pragma: no cover - guarded by FabricConfig.validate
        raise ValueError(f"unknown routing policy {fab.routing!r}")
    return src_rack * n_up + spine


# ----------------------------------------------------- loss recovery -------

def apply_recovery(cfg, proto, st, S, now, drained_msg, any_elig):
    """End-of-slot loss recovery (module doc, piece 2): refresh each
    message's last-arrival clock from this slot's drain, then rewind
    ``sent`` to ``recv`` for every message whose quiet period tripped
    the receiver's RESEND hook or the sender fallback timeout."""
    fl = cfg.fabric.faults
    M = S["size"].shape[0]
    last_arr = st["last_arr"].at[jnp.minimum(drained_msg, M - 1)].max(
        jnp.where(any_elig, now, 0), mode="drop")

    missing = (S["arrival"] <= now) & (st["completion"] < 0) \
        & (st["sent"] > st["recv"])
    ref_t = jnp.maximum(jnp.maximum(last_arr, st["last_rw"]), S["arrival"])
    quiet = now - ref_t
    known = st["recv"] > 0
    resend = proto.receiver.resend(cfg, st, S, now, known, quiet)
    rw = missing & (resend | (quiet >= fl.sender_timeout_slots))
    rewound = jnp.where(rw, st["sent"] - st["recv"], 0)
    out = {**st,
           "last_arr": last_arr,
           "sent": jnp.where(rw, st["recv"], st["sent"]),
           "retx": st["retx"] + rewound,
           "last_rw": jnp.where(rw, now, st["last_rw"])}
    if getattr(cfg, "ledger_on", False):
        # telemetry tap (DESIGN.md §8): per-slot rewind amounts split by
        # trigger, consumed by the event ledger at end of slot. RESEND
        # wins attribution when both timers fired the same slot.
        out["tr_resend"] = jnp.where(rw & resend, rewound, 0)
        out["tr_timeout"] = jnp.where(rw & ~resend, rewound, 0)
    return out


__all__ = ["FaultConfig", "link_down_mask", "host_down_mask",
           "init_fault_state", "inject_losses", "advance_ge",
           "forward_losses", "select_uplink", "apply_recovery"]
