"""Slotted packet-level datacenter network simulator, fully vectorized in JAX.

Faithful reproduction of Homa's mechanisms (paper §3) plus the comparison
protocols, as one ``lax.scan`` over link-time slots:

  senders     SRPT over sendable messages, blind until RTTbytes, then
              grant-clocked; per-chunk priorities (receiver-assigned)
  network     fixed delay (queueing modeled at downlinks, per paper §2.2)
  downlinks   8-level priority FIFOs per receiver (the TOR egress port);
              one slot drained per tick; exact priority-then-FIFO arbitration
  receivers   grants with controlled overcommitment (top-K SRPT, K = number
              of scheduled priority levels), dynamic scheduled priorities
              (lowest-levels-first to kill preemption lag, §3.4/Fig. 5),
              delayed visibility at senders (grant RTT)

Time unit: one slot = ``slot_bytes`` of link time (default 256 B ~ 205 ns at
10 Gbps; rtt_slots=38 -> RTTbytes ~ 9.7 KB as in the paper). All sizes are
tracked in slots; the final partial packet of a message occupies a full slot
(packetization overhead).

Protocols: homa | basic | phost | pias | pfabric | ndp  (see DESIGN.md for
the approximations in each baseline).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.workloads import MessageTable
from repro.core.priorities import PriorityAllocation, allocate_priorities, \
    pias_thresholds

I32 = jnp.int32
BIG = jnp.int32(2 ** 30)
MSG_BITS = 13
MSG_MOD = 1 << MSG_BITS          # max messages per sim


@dataclasses.dataclass(frozen=True)
class SimConfig:
    n_hosts: int = 16
    slot_bytes: int = 256
    n_prios: int = 8
    rtt_slots: int = 38                 # ~9.7 KB at 256 B slots
    net_delay_slots: int = 12           # sender NIC -> dst TOR eligibility
    grant_delay_slots: int = 19         # receiver decision -> sender visibility
    protocol: str = "homa"
    overcommit: int | None = None       # None: = n_sched (homa); basic: all
    ring_cap: int = 1024                # per-dst buffered chunks (TOR egress)
    phost_timeout_slots: int = 114      # ~3 RTT
    max_slots: int = 20_000

    @property
    def rtt_bytes(self) -> int:
        return self.rtt_slots * self.slot_bytes


def _to_slots(nbytes: np.ndarray, slot_bytes: int) -> np.ndarray:
    return np.maximum((nbytes + slot_bytes - 1) // slot_bytes, 1).astype(np.int32)


def prepare(cfg: SimConfig, table: MessageTable,
            alloc: PriorityAllocation | None = None,
            unsched_limit_bytes: int | np.ndarray | None = None):
    """Static per-message arrays for the scan."""
    M = len(table.size)
    assert M <= MSG_MOD, f"max {MSG_MOD} messages"
    assert cfg.max_slots < 2 ** 21
    size_slots = _to_slots(table.size, cfg.slot_bytes)

    if alloc is None:
        alloc = allocate_priorities(table.size, unsched_limit=cfg.rtt_bytes,
                                    n_prios=cfg.n_prios)

    if unsched_limit_bytes is None:
        unsched_limit_bytes = cfg.rtt_bytes
    ul = np.broadcast_to(np.asarray(unsched_limit_bytes), (M,))
    if cfg.protocol in ("pias", "pfabric"):
        ul = np.full((M,), cfg.rtt_bytes)       # blind first window
    unsched_slots = np.minimum(_to_slots(ul, cfg.slot_bytes), size_slots)

    if cfg.protocol == "homa":
        up = alloc.unsched_prio(table.size)
    elif cfg.protocol in ("phost", "ndp"):
        up = np.full((M,), cfg.n_prios - 1)     # one static unsched level
    else:                                        # basic / pias / pfabric
        up = np.zeros((M,))
    # PIAS: sender-side MLFQ demotion thresholds (slots of bytes sent)
    pias_cut = pias_thresholds(table.size, cfg.n_prios)
    pias_cut_slots = _to_slots(np.asarray(pias_cut + [1 << 40]),
                               cfg.slot_bytes) if pias_cut else \
        np.array([1 << 20], np.int32)

    static = {
        "src": jnp.asarray(table.src, I32),
        "dst": jnp.asarray(table.dst, I32),
        "size": jnp.asarray(size_slots, I32),
        "arrival": jnp.asarray(table.arrival_slot, I32),
        "unsched": jnp.asarray(unsched_slots, I32),
        "uprio": jnp.asarray(up, I32),
        "pias_cuts": jnp.asarray(pias_cut_slots, I32),
        "dst_onehot": jnp.asarray(
            np.arange(cfg.n_hosts)[:, None] == table.dst[None, :]),
        "msg_ids": jnp.arange(M, dtype=I32),
    }
    return static, alloc


def _init_state(cfg: SimConfig, M: int):
    H, cap, Dg = cfg.n_hosts, cfg.ring_cap, cfg.grant_delay_slots
    z = functools.partial(jnp.zeros, dtype=I32)
    return {
        "sent": z((M,)),
        "granted_s": z((M,)),                 # sender-visible grant (slots)
        "grant_r": z((M,)),                   # receiver-issued grant (slots)
        "recv": z((M,)),
        "sched_prio": z((M,)),
        "completion": jnp.full((M,), -1, I32),
        "stall_until": z((M,)),               # phost timeout blacklist
        "last_progress": z((M,)),
        "last_served": z((M,)),               # ndp fair share
        "last_sent": z((M,)),                 # pias sender fair share
        # downlink rings
        "r_msg": jnp.full((H, cap), -1, I32),
        "r_prio": jnp.full((H, cap), BIG, I32),   # smaller = served first
        "r_seq": jnp.full((H, cap), BIG, I32),
        "r_time": jnp.full((H, cap), BIG, I32),
        "r_valid": jnp.zeros((H, cap), bool),
        # delayed receiver state (grant/prio propagation)
        "hist_grant": z((Dg, M)),
        "hist_prio": z((Dg, M)),
        # stats
        "busy": z((H,)), "wasted": z((H,)), "lost": z(()),
        "q_sum": jnp.zeros((H,), jnp.float32), "q_max": z((H,)),
        "prio_drained": z((cfg.n_prios,)),
        "uplink_busy": z((H,)),
    }


def _receiver_grants(cfg: SimConfig, st, S, now, n_sched: int):
    """Compute receiver-side grants + scheduled priorities for this slot.
    Returns (grant_r, sched_prio, active_mask, withheld_exists (H,))."""
    size, dst_oh = S["size"], S["dst_onehot"]
    known = (st["recv"] > 0) & (st["completion"] < 0)
    remaining = jnp.maximum(size - st["recv"], 0)
    proto = cfg.protocol

    if proto in ("basic", "ndp"):
        grant_r = jnp.where(known, jnp.minimum(size, st["recv"] + cfg.rtt_slots),
                            st["grant_r"])
        grant_r = jnp.maximum(grant_r, st["grant_r"])
        return grant_r, jnp.zeros_like(st["sched_prio"]), known, \
            jnp.zeros((cfg.n_hosts,), bool)

    if proto in ("pias", "pfabric"):
        arrived = S["arrival"] <= now
        grant_r = jnp.where(arrived & (st["completion"] < 0),
                            jnp.minimum(size, st["recv"] + cfg.rtt_slots),
                            st["grant_r"])
        grant_r = jnp.maximum(grant_r, st["grant_r"])
        return grant_r, jnp.zeros_like(st["sched_prio"]), arrived, \
            jnp.zeros((cfg.n_hosts,), bool)

    # homa / phost: top-K SRPT per receiver
    K = 1 if proto == "phost" else (cfg.overcommit or max(n_sched, 1))
    K = min(K, size.shape[0])        # can't select more than M messages
    eligible = known
    if proto == "phost":
        eligible = eligible & (st["stall_until"] <= now)
    # encode (remaining, msg) so top_k recovers both; smaller remaining wins.
    # Ties break toward the SMALLEST msg id: a stable active set is what
    # gives SRPT its run-to-completion behaviour — an unstable tie-break
    # churns the active message and leaks grants to every tied message
    # (catastrophic under incast, where all messages are the same size).
    keyval = ((jnp.int32(1 << 17) - jnp.minimum(remaining, (1 << 17) - 1))
              << MSG_BITS) | (MSG_MOD - 1 - S["msg_ids"])
    mat = jnp.where(dst_oh & eligible[None, :], keyval[None, :], 0)  # (H, M)
    vals, _ = lax.top_k(mat, K)                                      # (H, K)
    valid = vals > 0
    msgs = jnp.where(valid, MSG_MOD - 1 - (vals & (MSG_MOD - 1)),
                     MSG_MOD)                                        # sentinel
    n_active = valid.sum(axis=1)                                     # (H,)
    # scheduled priority: rank r (0 = fewest remaining) among A active gets
    # level (A-1-r): lowest levels used first, shortest on top (paper §3.4)
    ranks = jnp.arange(K)[None, :]
    prio = jnp.clip(n_active[:, None] - 1 - ranks, 0, max(n_sched - 1, 0))

    flat_msgs = msgs.reshape(-1)
    new_grant = jnp.minimum(size, st["recv"] + cfg.rtt_slots)
    grant_r = st["grant_r"]
    grant_r = grant_r.at[flat_msgs].max(
        jnp.where(valid.reshape(-1), new_grant[
            jnp.minimum(flat_msgs, len(size) - 1)], 0), mode="drop")
    sched_prio = st["sched_prio"].at[flat_msgs].set(
        prio.reshape(-1), mode="drop")

    active = jnp.zeros_like(known).at[flat_msgs].set(
        valid.reshape(-1), mode="drop")
    withheld = (dst_oh & eligible[None, :] & ~active[None, :]).any(axis=1)
    return grant_r, sched_prio, active, withheld


def _sender_select(cfg: SimConfig, st, S, now):
    """Pick one message per host (SRPT or FIFO), return (chosen (H,), prio)."""
    size, src = S["size"], S["src"]
    arrived = S["arrival"] <= now
    sendable = arrived & (st["sent"] < st["granted_s"]) & (st["sent"] < size)
    remaining = jnp.maximum(size - st["sent"], 0)
    if cfg.protocol == "pias":
        # DCTCP-style hosts approximate per-flow fair sharing: round-robin
        order = jnp.minimum(st["last_sent"], (1 << 17) - 1)
    elif cfg.protocol == "ndp":
        order = jnp.minimum(S["arrival"], (1 << 17) - 1)    # FIFO senders
    else:
        order = jnp.minimum(remaining, (1 << 17) - 1)       # SRPT senders
    key = (order << MSG_BITS) | S["msg_ids"]
    key = jnp.where(sendable, key, BIG)
    host_min = jax.ops.segment_min(key, src, num_segments=cfg.n_hosts)
    has = host_min < BIG
    chosen = jnp.where(has, host_min & (MSG_MOD - 1), MSG_MOD)   # (H,)
    return chosen, has


def _chunk_prio(cfg: SimConfig, st, S, chosen, n_sched: int):
    """Priority value for the chunk each host sends (smaller = better)."""
    M = S["size"].shape[0]
    cm = jnp.minimum(chosen, M - 1)
    sent = st["sent"][cm]
    unsched = sent < S["unsched"][cm]
    proto = cfg.protocol
    if proto == "pfabric":
        # continuous priority: remaining slots
        return jnp.maximum(S["size"][cm] - sent, 0)
    if proto == "pias":
        lvl = jnp.searchsorted(S["pias_cuts"], sent, side="right")
        return lvl.astype(I32)                       # level 0 first, demoted up
    if proto in ("basic",):
        return jnp.zeros_like(cm)
    if proto == "ndp":
        return jnp.where(unsched, 0, 1).astype(I32)  # 2 static levels
    if proto == "phost":
        return jnp.where(unsched, 0, 1).astype(I32)
    # homa: receiver-allocated
    up = (cfg.n_prios - 1 - S["uprio"][cm])          # inverted: smaller=better
    sp = (n_sched - 1 - st["sched_prio"][cm]) + 0    # within scheduled band
    sched_inv = (cfg.n_prios - n_sched) + sp         # scheduled below unsched
    # unscheduled levels sit above (smaller inv value) all scheduled levels
    return jnp.where(unsched, up, sched_inv).astype(I32)


def step_fn(cfg: SimConfig, S, n_sched: int, st, now):
    H, cap, Dg = cfg.n_hosts, cfg.ring_cap, cfg.grant_delay_slots
    M = S["size"].shape[0]

    # ---- 1. receiver logic (current state), store into delay history
    grant_r, sched_prio, active, withheld = _receiver_grants(
        cfg, st, S, now, n_sched)
    st = {**st, "grant_r": grant_r, "sched_prio": sched_prio}
    hist_grant = st["hist_grant"].at[now % Dg].set(grant_r)
    hist_prio = st["hist_prio"].at[now % Dg].set(sched_prio)
    # sender sees the entry written Dg-1 slots ago
    vis_idx = (now + 1) % Dg
    grant_vis = hist_grant[vis_idx]
    prio_vis = hist_prio[vis_idx]

    arrived = S["arrival"] <= now
    blind = jnp.where(arrived, S["unsched"], 0)
    granted_s = jnp.maximum(jnp.maximum(st["granted_s"], blind), grant_vis)
    st = {**st, "granted_s": granted_s, "hist_grant": hist_grant,
          "hist_prio": hist_prio,
          "sched_prio": jnp.where(arrived, prio_vis, st["sched_prio"])}
    # NOTE: sender uses delayed sched_prio (the grant packet's priority)

    # ---- 2. senders pick + transmit one chunk
    chosen, has = _sender_select(cfg, st, S, now)
    cm = jnp.minimum(chosen, M - 1)
    prio_chunk = _chunk_prio(cfg, st, S, chosen, n_sched)
    sent = st["sent"].at[cm].add(jnp.where(has, 1, 0), mode="drop")
    last_sent = st["last_sent"].at[cm].set(
        jnp.where(has, now, st["last_sent"][cm]), mode="drop")
    st = {**st, "sent": sent, "last_sent": last_sent,
          "uplink_busy": st["uplink_busy"] + has.astype(I32)}

    # ---- 3. insert chunks into free buffer slots at the destination
    dsts = jnp.where(has, S["dst"][cm], H)                   # sentinel H
    same = (dsts[:, None] == dsts[None, :]) & has[None, :] & has[:, None]
    rank = jnp.sum(same & (jnp.arange(H)[None, :] < jnp.arange(H)[:, None]),
                   axis=1)                                    # rank within dst
    # r-th free (invalid) slot per dst row: true occupancy-based buffering;
    # a chunk is dropped only when the buffer is actually full.
    inv = ~st["r_valid"]                                      # (H, cap)
    c = jnp.cumsum(inv, axis=1)
    # pos_table[d, r] = index of the (r+1)-th invalid slot in row d
    ranks1 = jnp.arange(H)[None, None, :] + 1                 # (1, 1, H)
    matches = inv[:, :, None] & (c[:, :, None] == ranks1)     # (H, cap, H)
    pos_table = jnp.argmax(matches, axis=1)                   # (H, H)
    room = c[:, -1][jnp.minimum(dsts, H - 1)] > rank          # buffer not full
    okw = has & room
    lost = st["lost"] + jnp.sum(has & ~room)
    pos = pos_table[jnp.minimum(dsts, H - 1), rank]
    # suppressed writes go out of bounds (mode="drop"): an in-bounds no-op
    # write could race a genuine insertion at the same scatter location
    idx = (jnp.where(okw, dsts, H), jnp.where(okw, pos, 0))
    st = {**st,
          "r_msg": st["r_msg"].at[idx].set(cm, mode="drop"),
          "r_prio": st["r_prio"].at[idx].set(prio_chunk, mode="drop"),
          "r_seq": st["r_seq"].at[idx].set(
              jnp.full_like(dsts, now), mode="drop"),
          "r_time": st["r_time"].at[idx].set(
              jnp.full_like(dsts, now + cfg.net_delay_slots), mode="drop"),
          "r_valid": st["r_valid"].at[idx].set(
              jnp.ones_like(okw), mode="drop"),
          "lost": lost}

    # ---- 4. downlink drain: strict priority, FIFO within level
    eligible = st["r_valid"] & (st["r_time"] <= now)
    prio_eff = jnp.where(eligible, st["r_prio"], BIG)        # (H, cap)
    pmin = prio_eff.min(axis=1)                              # (H,)
    seq_eff = jnp.where(eligible & (st["r_prio"] == pmin[:, None]),
                        st["r_seq"], BIG)
    slot_idx = jnp.argmin(seq_eff, axis=1)                   # (H,)
    any_elig = pmin < BIG
    hidx = (jnp.arange(H), slot_idx)
    drained_msg = jnp.where(any_elig, st["r_msg"][hidx], M)
    recv = st["recv"].at[jnp.minimum(drained_msg, M - 1)].add(
        jnp.where(any_elig, 1, 0), mode="drop")
    r_valid = st["r_valid"].at[hidx].set(
        jnp.where(any_elig, False, st["r_valid"][hidx]))
    # ndp fair-share: round-robin via last-served ordering
    if cfg.protocol == "ndp":
        ls = st["last_served"].at[jnp.minimum(drained_msg, M - 1)].set(
            now, mode="drop")
        st = {**st, "last_served": ls}

    completion = jnp.where((recv >= S["size"]) & (st["completion"] < 0),
                           now, st["completion"])

    # ---- 5. stats
    qlen = (eligible.sum(axis=1) - any_elig.astype(I32))
    drained_prio = jnp.where(any_elig, jnp.minimum(
        pmin, cfg.n_prios - 1), 0)
    prio_drained = st["prio_drained"].at[drained_prio].add(
        jnp.where(any_elig, 1, 0), mode="drop")
    known_inc = (recv > 0) & (completion < 0)
    has_known = (S["dst_onehot"] & known_inc[None, :]).any(axis=1)
    wasted = st["wasted"] + (~any_elig & withheld & has_known).astype(I32)

    st = {**st, "recv": recv, "r_valid": r_valid, "completion": completion,
          "busy": st["busy"] + any_elig.astype(I32),
          "q_sum": st["q_sum"] + qlen.astype(jnp.float32),
          "q_max": jnp.maximum(st["q_max"], qlen),
          "wasted": wasted, "prio_drained": prio_drained}

    # ---- 6. phost timeout: if the single granted message makes no progress
    # for `timeout` slots, blacklist it briefly so the receiver switches to
    # another message (approximates pHost's sender-timeout mechanism).
    if cfg.protocol == "phost":
        lp = st["last_progress"]
        lp = jnp.maximum(lp, S["arrival"])            # clock starts at arrival
        lp = lp.at[jnp.minimum(drained_msg, M - 1)].max(
            jnp.where(any_elig, now, 0), mode="drop")
        timed_out = active & (st["grant_r"] > recv) &             (now - lp > cfg.phost_timeout_slots)
        new_stall = jnp.where(timed_out, now + cfg.phost_timeout_slots,
                              st["stall_until"])
        st = {**st, "stall_until": new_stall, "last_progress": lp}

    return st, None


@functools.partial(jax.jit, static_argnums=(0, 3))
def _run(cfg: SimConfig, S, st0, n_sched: int):
    body = functools.partial(step_fn, cfg, S, n_sched)
    st, _ = lax.scan(body, st0, jnp.arange(cfg.max_slots, dtype=I32))
    return st


def run_sim(cfg: SimConfig, table: MessageTable,
            alloc: PriorityAllocation | None = None,
            unsched_limit_bytes=None, return_state: bool = False) -> dict:
    S, alloc = prepare(cfg, table, alloc, unsched_limit_bytes)
    n_sched = alloc.n_sched if cfg.protocol == "homa" else \
        (cfg.overcommit or alloc.n_sched)
    n_sched = max(n_sched, 1)
    st0 = _init_state(cfg, len(table.size))
    st = _run(cfg, S, st0, n_sched)
    st = jax.tree.map(np.asarray, st)

    size_slots = np.asarray(S["size"])
    arrival = np.asarray(S["arrival"])
    done = st["completion"] >= 0
    elapsed = np.where(done, st["completion"] - arrival + 1, -1)
    ideal = size_slots + cfg.net_delay_slots
    slowdown = np.where(done, elapsed / ideal, np.nan)

    return {
        "alloc": alloc,
        "completion": st["completion"], "elapsed": elapsed,
        "ideal": ideal, "slowdown": slowdown, "done": done,
        "size_slots": size_slots, "size_bytes": np.asarray(table.size),
        "busy_frac": st["busy"] / cfg.max_slots,
        "wasted_frac": st["wasted"] / cfg.max_slots,
        "uplink_busy_frac": st["uplink_busy"] / cfg.max_slots,
        "q_mean_bytes": st["q_sum"] / cfg.max_slots * cfg.slot_bytes,
        "q_max_bytes": st["q_max"] * cfg.slot_bytes,
        "prio_drained_bytes": st["prio_drained"] * cfg.slot_bytes,
        "lost_chunks": int(st["lost"]),
        "n_complete": int(done.sum()), "n_messages": len(size_slots),
        **({"state": st, "static": jax.tree.map(np.asarray, S)}
           if return_state else {}),
    }


def slowdown_percentiles(stats: dict, pct: float = 99.0,
                         n_buckets: int = 10) -> dict:
    """Percentile slowdown bucketed by message size (paper Figs. 8/12)."""
    ok = stats["done"] & np.isfinite(stats["slowdown"])
    sizes = stats["size_bytes"][ok]
    sl = stats["slowdown"][ok]
    if len(sizes) == 0:
        return {"sizes": [], "p": [], "median": []}
    order = np.argsort(sizes)
    sizes, sl = sizes[order], sl[order]
    edges = np.linspace(0, len(sizes), n_buckets + 1).astype(int)
    out = {"sizes": [], "p": [], "median": [], "count": []}
    for i in range(n_buckets):
        lo, hi = edges[i], edges[i + 1]
        if hi <= lo:
            continue
        out["sizes"].append(float(np.median(sizes[lo:hi])))
        out["p"].append(float(np.percentile(sl[lo:hi], pct)))
        out["median"].append(float(np.percentile(sl[lo:hi], 50)))
        out["count"].append(int(hi - lo))
    return out
