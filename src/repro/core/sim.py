"""Slotted packet-level datacenter network simulator, fully vectorized in JAX.

Faithful reproduction of Homa's mechanisms (paper §3) plus the comparison
protocols, as one ``lax.scan`` over link-time slots:

  senders     chunk order + priority stamping from the protocol's
              ``SenderPolicy`` (SRPT for Homa), blind until RTTbytes,
              then grant-clocked; optionally gated by a host/NIC
              stage modeling per-chunk CPU cost and interrupt
              batching (``SimConfig.host``, ``repro.core.hostmodel``,
              DESIGN.md §10)
  network     fixed delay (single switch, the default), or a two-tier
              leaf-spine fabric with per-TOR uplink priority queues and
              configurable oversubscription (``SimConfig.fabric``,
              paper §5.2 topology — see ``repro.core.fabric``)
  downlinks   8-level priority FIFOs per receiver (the TOR egress port);
              one slot drained per tick; exact priority-then-FIFO arbitration
  receivers   grants + scheduled-priority assignment + overcommit degree
              from the protocol's ``ReceiverPolicy`` (Homa: top-K SRPT with
              controlled overcommitment, dynamic scheduled priorities
              lowest-levels-first, §3.4/Fig. 5), delayed visibility at
              senders (grant RTT); with a host model, drained chunks
              pass through a bounded per-host RX service FIFO before
              they reach ``recv`` — so software overhead delays grants
              AND completions (the §5.3 implementation-vs-sim gap)

Time unit: one slot = ``slot_bytes`` of link time (default 256 B ~ 205 ns at
10 Gbps; rtt_slots=38 -> RTTbytes ~ 9.7 KB as in the paper). All sizes are
tracked in slots; the final partial packet of a message occupies a full slot
(packetization overhead).

Protocols are pluggable policies (``repro.core.protocols``, DESIGN.md §1):
homa | basic | phost | pias | pfabric | ndp are registered out of the box
(see DESIGN.md §3 for the approximations in each baseline). ``step_fn`` is
policy-agnostic orchestration — it never inspects the protocol name.

The per-slot arbitration hot path (downlink drain, TOR uplink drain,
receiver grant-set top-K) is backend-dispatched (DESIGN.md §6):
``SimConfig.backend = "reference" | "pallas"`` (default from
``$SIM_BACKEND``) selects pure-jnp math or the ``kernels.arbiter``
Pallas kernels — bit-identical by contract, golden-tested.

Entry points:

  ``simulate(cfg, table)``    one run -> :class:`SimResult`
  ``run_sweep(cfg, spec)``    N independent runs described by one
                              :class:`repro.core.sweep.SweepSpec`: vmapped
                              per static-parameter group, optionally
                              device-sharded (``shard_map``) with chunked
                              scans + streaming stats (DESIGN.md §9)
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.workloads import MessageTable
from repro.core.priorities import PriorityAllocation, allocate_priorities, \
    pias_thresholds
from repro.core.protocols import (Protocol, get_protocol,
                                  registered_protocols, MSG_BITS, MSG_MOD,
                                  BIG, I32)
from repro.core.fabric import (FabricConfig, spine_hash, ring_insert,
                               drain_select, init_fabric_state,
                               route_chunks, uplink_drain)
from repro.core.faults import (FaultConfig, init_fault_state,
                               apply_recovery, host_down_mask,
                               link_down_mask)
from repro.core.hostmodel import HostConfig, as_host_config, get_host_model
from repro.core import telemetry
from repro.core.telemetry import TraceConfig, SimTrace
from repro.core.results import SimResult, bucketed_percentiles
from repro.kernels.arbiter.dispatch import resolve_backend, \
    resolve_interpret


@dataclasses.dataclass(frozen=True)
class SimConfig:
    n_hosts: int = 16
    slot_bytes: int = 256
    n_prios: int = 8
    rtt_slots: int = 38                 # ~9.7 KB at 256 B slots
    net_delay_slots: int = 12           # sender NIC -> dst TOR eligibility
    grant_delay_slots: int = 19         # receiver decision -> sender visibility
    protocol: str = "homa"
    overcommit: int | None = None       # None: = n_sched (homa); basic: all
    ring_cap: int = 1024                # per-dst buffered chunks (TOR egress)
    phost_timeout_slots: int = 114      # ~3 RTT
    max_slots: int = 20_000
    fabric: FabricConfig | None = None  # None: single switch (DESIGN.md §5)
    # host/NIC software-overhead stage (repro.core.hostmodel,
    # DESIGN.md §10): HostConfig | preset name ("ideal" | "kernel_stack"
    # | "kernel_bypass") | dict | None. None and zero-cost configs are
    # structurally skipped — bit-identical to the host-free simulator.
    host: HostConfig | str | dict | None = None
    # in-scan telemetry capture (repro.core.telemetry, DESIGN.md §8);
    # None (the default) keeps the scan free of every trace array and op
    # — bit-identical to the pre-telemetry simulator
    trace: TraceConfig | None = None
    # compute backend for the per-slot arbitration hot path (DESIGN.md §6):
    # "reference" (pure-jnp) | "pallas" (kernels.arbiter, one kernel per
    # stage) | "pallas_fused" (all of a slot's arbitration in ONE kernel
    # launch — DESIGN.md §11); None resolves from $SIM_BACKEND. All
    # backends are bit-identical by contract.
    backend: str | None = None
    # pallas interpret mode; None auto-selects (interpreted off-TPU,
    # $SIM_PALLAS_INTERPRET overrides). Resolved to a concrete bool here
    # so jit retraces when the effective mode changes.
    pallas_interpret: bool | None = None

    def __post_init__(self):
        get_protocol(self.protocol)     # ValueError on unknown protocol
        object.__setattr__(self, "backend", resolve_backend(self.backend))
        object.__setattr__(self, "pallas_interpret",
                           resolve_interpret(self.pallas_interpret))
        if self.fabric is not None:
            self.fabric.validate(self.n_hosts)
        object.__setattr__(self, "host", as_host_config(self.host))
        if self.host is not None:
            self.host.validate()
        # JSON round-trip convenience: accept a plain dict for trace
        if isinstance(self.trace, dict):
            object.__setattr__(self, "trace", TraceConfig(**self.trace))
        if self.trace is not None:
            self.trace.validate()

    @property
    def rtt_bytes(self) -> int:
        return self.rtt_slots * self.slot_bytes

    @property
    def fabric_on(self) -> bool:
        """True iff the leaf-spine tier is modeled (``FabricConfig(None)``
        and ``fabric=None`` both mean the single-switch fast path)."""
        return self.fabric is not None and self.fabric.enabled

    @property
    def fused_on(self) -> bool:
        """True iff the fused per-slot mega-kernel backend is selected
        (DESIGN.md §11). Stages whose hoist-to-slot-start precondition a
        config doesn't meet (a zero ``net_delay_slots`` / ``leaf_delay_
        slots`` makes same-slot insertions immediately eligible) fall
        back to the staged pallas kernels per stage — still
        bit-identical, never wrong."""
        return self.backend == "pallas_fused"

    @property
    def faults_on(self) -> bool:
        """True iff the fault/recovery layer is active (DESIGN.md §7).
        Faults hang off the fabric tier; ``fabric.faults=None`` (the
        default) keeps the scan loss-free and bit-identical to the
        pre-fault simulator."""
        return self.fabric_on and self.fabric.faults is not None

    @property
    def trace_on(self) -> bool:
        """True iff in-scan telemetry capture is active (DESIGN.md §8).
        ``trace=None`` and ``TraceConfig(enabled=False)`` both keep the
        scan bit-identical to the untraced simulator."""
        return self.trace is not None and self.trace.enabled

    @property
    def ledger_on(self) -> bool:
        """True iff the protocol event ledger is captured (``trace_on``
        with a nonzero ``ledger_cap``)."""
        return self.trace_on and self.trace.ledger_cap > 0

    @property
    def host_on(self) -> bool:
        """True iff an active host/NIC stage is modeled (DESIGN.md §10).
        ``host=None`` and zero-overhead configs (the ``ideal`` preset)
        are structurally skipped — the scan is bit-identical to the
        host-free simulator (golden-enforced)."""
        return self.host is not None and not self.host.is_ideal

    @property
    def host_tx_on(self) -> bool:
        """Send-side host gate active (nonzero TX cost)."""
        return self.host_on and self.host.tx_on

    @property
    def host_rx_on(self) -> bool:
        """Receive-side host FIFO active (nonzero RX cost)."""
        return self.host_on and self.host.rx_on

    @property
    def host_model(self):
        """The registered :class:`repro.core.hostmodel.HostModel`."""
        return get_host_model(self.host.model)


def _to_slots(nbytes: np.ndarray, slot_bytes: int) -> np.ndarray:
    return np.maximum((nbytes + slot_bytes - 1) // slot_bytes, 1).astype(np.int32)


def prepare(cfg: SimConfig, table: MessageTable,
            alloc: PriorityAllocation | None = None,
            unsched_limit_bytes: int | np.ndarray | None = None):
    """Static per-message arrays for the scan."""
    proto = get_protocol(cfg.protocol)
    M = len(table.size)
    if M > MSG_MOD:
        raise ValueError(
            f"table has {M} messages but the simulator's packed sort keys "
            f"hold at most {MSG_MOD} (MSG_BITS={MSG_BITS}); split the "
            f"table into shorter runs or raise MSG_BITS in protocols.py")
    if cfg.max_slots >= 2 ** 21:
        raise ValueError(
            f"max_slots={cfg.max_slots} overflows the int32 sort-key "
            f"encoding (limit 2**21-1 = {2 ** 21 - 1}); lower max_slots "
            f"or coarsen slot_bytes so the horizon fits")
    size_slots = _to_slots(table.size, cfg.slot_bytes)

    if alloc is None:
        alloc = allocate_priorities(table.size, unsched_limit=cfg.rtt_bytes,
                                    n_prios=cfg.n_prios)

    ul = proto.unsched_limit(cfg, M, unsched_limit_bytes)
    unsched_slots = np.minimum(_to_slots(ul, cfg.slot_bytes), size_slots)
    up = proto.unsched_prio(cfg, table.size, alloc)

    # PIAS: sender-side MLFQ demotion thresholds (slots of bytes sent)
    pias_cut = pias_thresholds(table.size, cfg.n_prios)
    pias_cut_slots = _to_slots(np.asarray(pias_cut + [1 << 40]),
                               cfg.slot_bytes) if pias_cut else \
        np.array([1 << 20], np.int32)

    # unloaded baseline (slots): cross-rack chunks traverse leaf + spine,
    # so a fabric with non-default delays keeps slowdown anchored at 1.0.
    # Static so streaming sweeps can bin slowdowns inside the scan
    # (repro.core.sweep, DESIGN.md §9); _finalize reads it back.
    net_delay = np.full(M, cfg.net_delay_slots, np.int64)
    if cfg.fabric_on:
        rs = cfg.fabric.rack_size(cfg.n_hosts)
        cross = (table.src // rs) != (table.dst // rs)
        net_delay = np.where(cross, cfg.fabric.leaf_delay_slots
                             + cfg.fabric.spine_delay_slots, net_delay)

    static = {
        "src": jnp.asarray(table.src, I32),
        "dst": jnp.asarray(table.dst, I32),
        "size": jnp.asarray(size_slots, I32),
        "arrival": jnp.asarray(table.arrival_slot, I32),
        "unsched": jnp.asarray(unsched_slots, I32),
        "uprio": jnp.asarray(up, I32),
        "pias_cuts": jnp.asarray(pias_cut_slots, I32),
        "dst_onehot": jnp.asarray(
            np.arange(cfg.n_hosts)[:, None] == table.dst[None, :]),
        "msg_ids": jnp.arange(M, dtype=I32),
        "ideal": jnp.asarray(size_slots + net_delay, I32),
    }
    if cfg.fabric_on:
        # per-message ECMP spine choice (seeded, deterministic) — only
        # fabric-enabled configs carry the extra static array
        static["spine"] = jnp.asarray(spine_hash(
            table.src, table.dst, np.arange(M), cfg.fabric.seed,
            cfg.fabric.n_uplinks(cfg.n_hosts)), I32)
    return static, alloc


def _init_state(cfg: SimConfig, proto: Protocol, M: int):
    H, cap, Dg = cfg.n_hosts, cfg.ring_cap, cfg.grant_delay_slots
    z = functools.partial(jnp.zeros, dtype=I32)
    return {
        **proto.extra_state(cfg, M),          # protocol-private carry
        **(init_fabric_state(cfg) if cfg.fabric_on else {}),
        **(init_fault_state(cfg, M) if cfg.faults_on else {}),
        **(cfg.host_model.init_state(cfg, M) if cfg.host_on else {}),
        **(telemetry.init_trace_state(cfg, M) if cfg.trace_on else {}),
        "sent": z((M,)),
        "granted_s": z((M,)),                 # sender-visible grant (slots)
        "grant_r": z((M,)),                   # receiver-issued grant (slots)
        "recv": z((M,)),
        "sched_prio": z((M,)),
        "completion": jnp.full((M,), -1, I32),
        # downlink rings; a chunk's network-arrival time is r_seq +
        # net_delay_slots (enqueue time plus the fixed network delay), so
        # no separate r_time array is carried
        "r_msg": jnp.full((H, cap), -1, I32),
        "r_prio": jnp.full((H, cap), BIG, I32),   # smaller = served first
        "r_seq": jnp.full((H, cap), BIG, I32),
        "r_valid": jnp.zeros((H, cap), bool),
        # delayed receiver state (grant/prio propagation)
        "hist_grant": z((Dg, M)),
        "hist_prio": z((Dg, M)),
        # stats
        "busy": z((H,)), "wasted": z((H,)), "lost": z(()),
        "q_sum": jnp.zeros((H,), jnp.float32), "q_max": z((H,)),
        "prio_drained": z((cfg.n_prios,)),
        "uplink_busy": z((H,)),
    }


def _sender_select(cfg: SimConfig, proto: Protocol, st, S, now):
    """Pick one message per host by the sender policy's order key."""
    size, src = S["size"], S["src"]
    arrived = S["arrival"] <= now
    sendable = arrived & (st["sent"] < st["granted_s"]) & (st["sent"] < size)
    remaining = jnp.maximum(size - st["sent"], 0)
    order = proto.sender.order(cfg, st, S, now, remaining)
    key = (order << MSG_BITS) | S["msg_ids"]
    key = jnp.where(sendable, key, BIG)
    host_min = jax.ops.segment_min(key, src, num_segments=cfg.n_hosts)
    has = host_min < BIG
    chosen = jnp.where(has, host_min & (MSG_MOD - 1), MSG_MOD)   # (H,)
    return chosen, has


def _fused_precompute(cfg: SimConfig, proto: Protocol, S, n_sched: int,
                      st, now):
    """``pallas_fused`` backend (DESIGN.md §11): solve ALL of this slot's
    arbitration — downlink drain, TOR uplink drain, SRPT grant top-K —
    in one kernel launch at slot start, before the stages that normally
    interleave with them. Returns ``(st, grant_st, fused)``:

      st        slot state, with the host RX delivery already applied
                when the downlink stage is fused (its room gate is a
                kernel input; ``rx_deliver`` touches only RX-ring state
                and ``recv``, which stages 1–3 never read)
      grant_st  the state the receiver policy must see — slot-start
                ``recv`` (grants run before RX delivery in the staged
                order), everything else current
      fused     per-stage pre-solved answers: ``"down"``/``"up"`` ->
                the ``drain_select`` triple, ``"topk"`` -> ``(vals,
                idx)`` for ``ReceiverPolicy.grants``

    Hoisting the drains is bit-exact because every chunk inserted later
    in the slot is ineligible until the next slot (``net_delay_slots >=
    1`` / ``leaf_delay_slots >= 1`` / validated ``spine_delay_slots >=
    1``) and ``ring_insert`` only ever writes invalid slots, so the
    winners and their payloads are unchanged. A stage whose delay
    precondition fails is simply not fused — the staged kernel runs at
    its usual point instead."""
    from repro.kernels.arbiter import dispatch
    fuse_down = cfg.net_delay_slots >= 1
    fuse_up = cfg.fabric_on and cfg.fabric.leaf_delay_slots >= 1
    prob = proto.receiver.grant_problem(cfg, st, S, now, n_sched)
    grant_st = st
    down = up = None
    if fuse_down:
        if cfg.host_rx_on:
            recv_pre = st["recv"]
            st = cfg.host_model.rx_deliver(cfg, st, S, now)
            room = cfg.host_model.rx_room(cfg, st)
            grant_st = {**st, "recv": recv_pre}
        eligible = st["r_valid"] & (st["r_seq"] + cfg.net_delay_slots
                                    <= now)
        if cfg.faults_on and cfg.fabric.faults.tor_fail:
            eligible = eligible & ~host_down_mask(cfg, now)[:, None]
        if cfg.host_rx_on:
            st = {**st, "h_rx_stall": st["h_rx_stall"]
                  + (eligible.any(axis=1) & ~room).astype(I32)}
            eligible = eligible & room[:, None]
        down = (st["r_prio"], st["r_seq"], eligible)
    if fuse_up:
        fab = cfg.fabric
        u_elig = st["u_valid"] & (st["u_seq"] + fab.leaf_delay_slots
                                  <= now)
        fl = fab.faults
        if fl is not None and (fl.link_fail or fl.tor_fail):
            u_elig = u_elig & ~link_down_mask(cfg, now)[:, None]
        up = (st["u_prio"], st["u_seq"], u_elig)
    if down is None and up is None and prob is None:
        return st, grant_st, {}
    out = dispatch.fused_slot(down=down, up=up, topk=prob,
                              interpret=cfg.pallas_interpret)
    fused = {}
    if "down" in out:
        bp, bi = out["down"]
        fused["down"] = (bi, bp < BIG, bp)
    if "up" in out:
        bp, bi = out["up"]
        fused["up"] = (bi, bp < BIG, bp)
    if "topk" in out:
        fused["topk"] = out["topk"]
    return st, grant_st, fused


def step_fn(cfg: SimConfig, proto: Protocol, S, n_sched: int, st, now):
    """One link-time slot: policy-agnostic orchestration of receivers,
    uplinks, the network, and the priority-queue downlinks."""
    H, cap, Dg = cfg.n_hosts, cfg.ring_cap, cfg.grant_delay_slots
    M = S["size"].shape[0]

    # pre-step references for telemetry event deltas (DESIGN.md §8)
    tr_prev = telemetry.snapshot(cfg, st) if cfg.trace_on else None

    # ---- 0. fused backend: one kernel for ALL of this slot's
    # arbitration (DESIGN.md §11); {} when nothing is fusable
    grant_st, fused = st, {}
    if cfg.fused_on:
        st, grant_st, fused = _fused_precompute(cfg, proto, S, n_sched,
                                                st, now)

    # ---- 1. receiver policy (current state), store into delay history
    grant_r, sched_prio, active, withheld = proto.receiver.grants(
        cfg, grant_st, S, now, n_sched, topk=fused.get("topk"))
    st = {**st, "grant_r": grant_r, "sched_prio": sched_prio}
    hist_grant = st["hist_grant"].at[now % Dg].set(grant_r)
    hist_prio = st["hist_prio"].at[now % Dg].set(sched_prio)
    # sender sees the entry written Dg-1 slots ago
    vis_idx = (now + 1) % Dg
    grant_vis = hist_grant[vis_idx]
    prio_vis = hist_prio[vis_idx]

    arrived = S["arrival"] <= now
    blind = jnp.where(arrived, S["unsched"], 0)
    granted_s = jnp.maximum(jnp.maximum(st["granted_s"], blind), grant_vis)
    st = {**st, "granted_s": granted_s, "hist_grant": hist_grant,
          "hist_prio": hist_prio,
          "sched_prio": jnp.where(arrived, prio_vis, st["sched_prio"])}
    # NOTE: sender uses delayed sched_prio (the grant packet's priority)

    # ---- 2. senders pick + transmit one chunk (sender policy)
    chosen, has = _sender_select(cfg, proto, st, S, now)
    if cfg.host_tx_on:
        # host/NIC stage (DESIGN.md §10): the selected chunk only makes
        # the wire if the host's TX CPU budget covers it this slot
        has, st = cfg.host_model.host_tx(cfg, st, has, now)
    cm = jnp.minimum(chosen, M - 1)
    unsched_chunk = st["sent"][cm] < S["unsched"][cm]
    prio_chunk = proto.sender.chunk_prio(cfg, st, S, cm, unsched_chunk,
                                         n_sched)
    sent = st["sent"].at[cm].add(jnp.where(has, 1, 0), mode="drop")
    st = {**st, "sent": sent,
          "uplink_busy": st["uplink_busy"] + has.astype(I32)}
    st = proto.sender.on_send(cfg, st, S, cm, has, now)

    # ---- 3. route chunks into the first queueing tier. Single switch:
    # straight into the destination downlink ring (true occupancy-based
    # buffering; a chunk drops only when the ring is actually full).
    # Leaf-spine fabric: same-rack chunks switch at the leaf, cross-rack
    # chunks enter their TOR's hashed uplink queue, and each uplink
    # drains one chunk per slot toward the destination downlink.
    dsts = jnp.where(has, S["dst"][cm], H)                   # sentinel H
    if not cfg.fabric_on:
        r_msg, r_prio, r_seq, r_valid, n_drop = ring_insert(
            st["r_msg"], st["r_prio"], st["r_seq"], st["r_valid"],
            dsts, has, cm, prio_chunk, jnp.full_like(dsts, now))
        st = {**st, "r_msg": r_msg, "r_prio": r_prio, "r_seq": r_seq,
              "r_valid": r_valid, "lost": st["lost"] + n_drop}
    else:
        st = route_chunks(cfg, st, S, cm, has, dsts, prio_chunk, now)
        st = uplink_drain(cfg, st, S, now, pre=fused.get("up"))

    # ---- 4. downlink drain: strict priority, FIFO within level
    # (backend-dispatched: cfg.backend="pallas" runs the priority_arbiter
    # kernel, bit-identical to the reference math — DESIGN.md §6)
    eligible = st["r_valid"] & (st["r_seq"] + cfg.net_delay_slots <= now)
    if cfg.faults_on and cfg.fabric.faults.tor_fail:
        # hosts behind a failed TOR drain nothing for the window; their
        # buffered chunks survive and resume draining when it lifts
        eligible = eligible & ~host_down_mask(cfg, now)[:, None]
    q_eligible = eligible                       # backlog incl. stalled rows
    if "down" in fused:
        # winner pre-solved at slot start by the fused kernel (incl. the
        # RX delivery / room gate — _fused_precompute); this slot's
        # insertions carry seq == now and can't be eligible yet, so the
        # hoisted selection is bit-identical (DESIGN.md §11). q_eligible
        # above is provably the kernel's pre-room eligibility input.
        slot_idx, any_elig, pmin = fused["down"]
    else:
        if cfg.host_rx_on:
            # host/NIC RX stage (DESIGN.md §10): finish service on ring
            # entries whose CPU time elapsed (feeds recv -> grants AND
            # completions), then gate the downlink on RX-ring room — a
            # full ring backpressures the network (chunks stay queued,
            # not lost)
            hm = cfg.host_model
            st = hm.rx_deliver(cfg, st, S, now)
            room = hm.rx_room(cfg, st)
            st = {**st, "h_rx_stall": st["h_rx_stall"]
                  + (eligible.any(axis=1) & ~room).astype(I32)}
            eligible = eligible & room[:, None]
        slot_idx, any_elig, pmin = drain_select(
            st["r_prio"], st["r_seq"], eligible, backend=cfg.backend,
            interpret=cfg.pallas_interpret)
    hidx = (jnp.arange(H), slot_idx)
    drained_msg = jnp.where(any_elig, st["r_msg"][hidx], M)
    if cfg.host_rx_on:
        # drained chunks enter the RX ring; recv advances in rx_deliver
        st = cfg.host_model.rx_accept(cfg, st, S, drained_msg, any_elig,
                                      now)
        recv = st["recv"]
    else:
        recv = st["recv"].at[jnp.minimum(drained_msg, M - 1)].add(
            jnp.where(any_elig, 1, 0), mode="drop")
    r_valid = st["r_valid"].at[hidx].set(
        jnp.where(any_elig, False, st["r_valid"][hidx]))
    st = proto.on_drain(cfg, st, S, drained_msg, any_elig, now)

    completion = jnp.where((recv >= S["size"]) & (st["completion"] < 0),
                           now, st["completion"])

    # ---- 5. stats
    qlen = (q_eligible.sum(axis=1) - any_elig.astype(I32))
    drained_prio = jnp.where(any_elig, jnp.minimum(
        pmin, cfg.n_prios - 1), 0)
    prio_drained = st["prio_drained"].at[drained_prio].add(
        jnp.where(any_elig, 1, 0), mode="drop")
    known_inc = (recv > 0) & (completion < 0)
    has_known = (S["dst_onehot"] & known_inc[None, :]).any(axis=1)
    wasted = st["wasted"] + (~any_elig & withheld & has_known).astype(I32)

    st = {**st, "recv": recv, "r_valid": r_valid, "completion": completion,
          "busy": st["busy"] + any_elig.astype(I32),
          "q_sum": st["q_sum"] + qlen.astype(jnp.float32),
          "q_max": jnp.maximum(st["q_max"], qlen),
          "wasted": wasted, "prio_drained": prio_drained}

    # ---- 5b. loss recovery (fault-enabled fabrics only, DESIGN.md §7):
    # receiver RESENDs + sender fallback timeouts rewind quiet messages'
    # send offsets so fault-dropped chunks get retransmitted
    if cfg.faults_on:
        st = apply_recovery(cfg, proto, st, S, now, drained_msg, any_elig)

    # ---- 6. protocol end-of-slot hook (e.g. pHost sender timeouts)
    st = proto.post_step(cfg, st, S, now, active, drained_msg, any_elig)

    # ---- 7. telemetry capture (ledger append + strided series rows)
    if cfg.trace_on:
        st = telemetry.capture_slot(cfg, st, S, now, tr_prev, active, qlen)

    return st, None


@functools.partial(jax.jit, static_argnums=(0, 1, 4))
def _run(cfg: SimConfig, proto: Protocol, S, st0, n_sched: int):
    body = functools.partial(step_fn, cfg, proto, S, n_sched)
    st, _ = lax.scan(body, st0, jnp.arange(cfg.max_slots, dtype=I32))
    return st


@functools.partial(jax.jit, static_argnums=(0, 1, 3))
def _run_batch(cfg: SimConfig, proto: Protocol, S_stack, n_sched: int):
    """N independent runs in one trace: vmap over the leading table axis."""
    M = S_stack["size"].shape[1]
    st0 = _init_state(cfg, proto, M)

    def one(S):
        body = functools.partial(step_fn, cfg, proto, S, n_sched)
        st, _ = lax.scan(body, st0, jnp.arange(cfg.max_slots, dtype=I32))
        return st

    return jax.vmap(one)(S_stack)


def _finalize(cfg: SimConfig, table: MessageTable, S, alloc, st,
              return_state: bool, reduce_trace: bool = False,
              timings: dict | None = None) -> SimResult:
    """Numpy post-processing of one run's final scan state.

    ``reduce_trace=True`` (the ``run_sweep`` path) keeps only the
    streaming-stat scalars of a captured trace — vmapped sweeps never
    hold N full ``SimTrace`` histories at once (DESIGN.md §8)."""
    size_slots = np.asarray(S["size"])
    arrival = np.asarray(S["arrival"])
    done = st["completion"] >= 0
    elapsed = np.where(done, st["completion"] - arrival + 1, -1)
    ideal = np.asarray(S["ideal"]).astype(np.int64)   # set by prepare()
    slowdown = np.where(done, elapsed / ideal, np.nan)

    fabric = None
    tor_kw = {}
    if cfg.fabric_on:
        fab = cfg.fabric
        fabric = {"racks": fab.racks,
                  "rack_size": fab.rack_size(cfg.n_hosts),
                  "n_uplinks": fab.n_uplinks(cfg.n_hosts),
                  "oversub": fab.oversub, "seed": fab.seed,
                  "routing": fab.routing}
        tor_kw = dict(
            tor_up_busy_frac=st["u_busy"] / cfg.max_slots,
            tor_up_q_mean_bytes=st["u_q_sum"] / cfg.max_slots
            * cfg.slot_bytes,
            tor_up_q_max_bytes=st["u_q_max"] * cfg.slot_bytes,
            tor_up_lost_chunks=int(st["u_lost"]))
    if cfg.faults_on:
        fl = cfg.fabric.faults
        first_loss = np.asarray(st["first_loss"])
        affected = first_loss < 2 ** 30
        # recovery time: first fault-drop on the message -> completion;
        # -1 for messages never hit (or never finished)
        tor_kw.update(
            faults=dataclasses.asdict(fl),
            retx_chunks=np.asarray(st["retx"]),
            msg_lost_chunks=np.asarray(st["msg_lost"]),
            recovery_slots=np.where(done & affected,
                                    np.asarray(st["completion"])
                                    - first_loss, -1),
            fault_lost_chunks=int(st["f_lost"]))
    if cfg.host_on:
        from repro.core.hostmodel import QSCALE
        tor_kw["host"] = dataclasses.asdict(cfg.host)
        if cfg.host_tx_on:
            tor_kw.update(
                host_tx_busy_frac=st["h_tx_work_q"]
                / (cfg.max_slots * QSCALE),
                host_tx_defer_frac=st["h_tx_defer"] / cfg.max_slots)
        if cfg.host_rx_on:
            tor_kw.update(
                host_rx_stall_frac=st["h_rx_stall"] / cfg.max_slots,
                host_rx_q_mean_chunks=st["h_rx_q_sum"] / cfg.max_slots,
                host_rx_q_max_chunks=np.asarray(st["h_rx_q_max"]))

    trace = trace_summary = None
    if cfg.trace_on:
        tr = telemetry.finalize_trace(cfg, st, timings)
        trace_summary = tr.reduce()
        if not reduce_trace:
            trace = tr
    elif timings is not None:
        # wallclock-only run (capture disabled): keep the stage split
        trace_summary = {"timings": timings}

    return SimResult(
        protocol=cfg.protocol, alloc=alloc,
        completion=st["completion"], elapsed=elapsed, ideal=ideal,
        slowdown=slowdown, done=done,
        size_slots=size_slots, size_bytes=np.asarray(table.size),
        busy_frac=st["busy"] / cfg.max_slots,
        wasted_frac=st["wasted"] / cfg.max_slots,
        uplink_busy_frac=st["uplink_busy"] / cfg.max_slots,
        q_mean_bytes=st["q_sum"] / cfg.max_slots * cfg.slot_bytes,
        q_max_bytes=st["q_max"] * cfg.slot_bytes,
        prio_drained_bytes=st["prio_drained"] * cfg.slot_bytes,
        lost_chunks=int(st["lost"]) + int(st.get("u_lost", 0)),
        n_complete=int(done.sum()), n_messages=len(size_slots),
        fabric=fabric, **tor_kw,
        trace=trace, trace_summary=trace_summary,
        state=st if return_state else None,
        static=jax.tree.map(np.asarray, S) if return_state else None,
    )


def simulate(cfg: SimConfig, table: MessageTable,
             alloc: PriorityAllocation | None = None,
             unsched_limit_bytes=None,
             return_state: bool = False) -> SimResult:
    """Run one simulation; returns a structured :class:`SimResult`.

    With ``cfg.trace = TraceConfig(wallclock=True)`` the scan runs
    through jax's AOT path and the exact trace / compile / execute
    wall-clock split lands in ``result.trace.timings``."""
    proto = get_protocol(cfg.protocol)
    S, alloc = prepare(cfg, table, alloc, unsched_limit_bytes)
    n_sched = proto.n_sched(cfg, alloc)
    st0 = _init_state(cfg, proto, len(table.size))
    timings = None
    if cfg.trace is not None and cfg.trace.wallclock:
        # wallclock instrumentation works with capture disabled too
        # (TraceConfig(enabled=False, wallclock=True)): the timings of
        # the UNTRACED program, for capture-overhead measurement
        st, timings = telemetry.timed_aot_run(
            _run, (cfg, proto, S, st0, n_sched), (S, st0),
            repeats=cfg.trace.wallclock_repeats)
    else:
        st = _run(cfg, proto, S, st0, n_sched)
    st = jax.tree.map(np.asarray, st)
    return _finalize(cfg, table, S, alloc, st, return_state,
                     timings=timings)


def run_sweep(cfg: SimConfig, spec) -> list:
    """Run N independent simulations batched inside one jit trace per
    static-parameter group, optionally sharded across devices with
    chunked scans and streaming statistics.

    The sweep is described by a single :class:`repro.core.sweep.SweepSpec`
    (DESIGN.md §9)::

        run_sweep(cfg, SweepSpec(seeds=(0, 1, 2, 3), workload="W1",
                                 load=0.8, shared_alloc=True,
                                 shard=True, chunk_slots=512,
                                 streaming=True))

    Returns one result per run, in input order: :class:`SimResult` for
    exact sweeps, :class:`repro.core.sweep.SweepStats` (bounded-memory
    streaming accumulators) when ``spec.streaming`` is set. Runs are
    grouped by ``(table length, scheduled levels)`` — the scan's static
    parameters — and each group compiles once; ``shared_alloc=True``
    derives one priority allocation from the union of all tables' sizes
    (the paper's workload-knowledge model, §4) so a same-length sweep
    compiles exactly once. With chunking/sharding/streaming off, results
    are bit-identical to sequential :func:`simulate` calls.
    """
    from repro.core import sweep as sweep_mod
    if not isinstance(spec, sweep_mod.SweepSpec):
        raise TypeError(
            f"run_sweep(cfg, spec) takes a SweepSpec, got "
            f"{type(spec).__name__}. The legacy kwargs signature (and "
            f"run_sim) were removed after their deprecation release; "
            f"build a SweepSpec: run_sweep(cfg, SweepSpec(seeds=..., "
            f"workload=..., load=...)) — or pass tables=(...).")
    return sweep_mod.run_spec(cfg, spec)


def slowdown_percentiles(stats: dict | SimResult, pct: float = 99.0,
                         n_buckets: int = 10) -> dict:
    """Percentile slowdown bucketed by message size (paper Figs. 8/12).
    Accepts a :class:`SimResult` or the legacy stats dict."""
    if isinstance(stats, SimResult):
        return stats.percentiles_by_size(pct, n_buckets)
    return bucketed_percentiles(stats["size_bytes"], stats["slowdown"],
                                stats["done"], pct, n_buckets)


__all__ = ["SimConfig", "FabricConfig", "TraceConfig", "SimTrace",
           "HostConfig", "simulate", "run_sweep",
           "slowdown_percentiles", "prepare", "step_fn", "SimResult",
           "registered_protocols"]


def __getattr__(name):
    # late-bound so `from repro.core.sim import SweepSpec` works without
    # importing the sweep engine at module load (sweep imports sim)
    if name in ("SweepSpec", "StreamSpec", "SweepStats"):
        from repro.core import sweep as sweep_mod
        return getattr(sweep_mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
