"""Homa's receiver-side priority allocation (paper §3.4, Fig. 4).

Given a sample of the receiver's message-size distribution:
  1. compute the fraction of bytes that arrive unscheduled
     (min(size, unsched_limit) per message),
  2. allocate that fraction of the 8 levels (the highest ones) to
     unscheduled traffic, at least 1 each side when both kinds exist,
  3. choose size cutoffs between unscheduled levels so each level carries
     an equal share of unscheduled bytes (shortest messages -> highest
     priority).

The paper's implementation precomputes these from workload knowledge (§4);
we do the same, plus an online estimator (beyond-paper) in HomaReceiverState.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class PriorityAllocation:
    n_prios: int
    n_unsched: int                 # highest n_unsched levels are unscheduled
    cutoffs: tuple[int, ...]       # len n_unsched-1, ascending message sizes
    unsched_bytes_frac: float

    @property
    def n_sched(self) -> int:
        return self.n_prios - self.n_unsched

    @property
    def sched_lo(self) -> int:
        return 0

    @property
    def sched_hi(self) -> int:
        return self.n_sched - 1

    def unsched_prio(self, msg_size: np.ndarray) -> np.ndarray:
        """Priority level for unscheduled packets of messages of given size.
        Highest level (n_prios-1) for the shortest messages."""
        lvl = np.searchsorted(np.asarray(self.cutoffs), msg_size, side="left")
        return (self.n_prios - 1 - lvl).astype(np.int32)


def allocate_priorities(sizes: np.ndarray, *, unsched_limit: int,
                        n_prios: int = 8,
                        force_unsched: int | None = None) -> PriorityAllocation:
    sizes = np.asarray(sizes, np.int64)
    unsched_bytes = np.minimum(sizes, unsched_limit).astype(np.float64)
    frac = float(unsched_bytes.sum() / max(sizes.sum(), 1))
    if force_unsched is not None:
        n_unsched = force_unsched
    else:
        n_unsched = int(round(frac * n_prios))
        n_unsched = min(max(n_unsched, 1), n_prios - 1)
    cutoffs = equal_bytes_cutoffs(sizes, unsched_bytes, n_unsched)
    return PriorityAllocation(n_prios, n_unsched, tuple(cutoffs), frac)


def equal_bytes_cutoffs(sizes: np.ndarray, weights: np.ndarray,
                        n_levels: int) -> list[int]:
    """Size thresholds splitting `weights` into n_levels equal-byte buckets
    by ascending size (paper Fig. 4's equal-traffic rule)."""
    if n_levels <= 1:
        return []
    order = np.argsort(sizes, kind="stable")
    s_sorted = sizes[order]
    w_cum = np.cumsum(weights[order])
    total = w_cum[-1]
    cuts = []
    for i in range(1, n_levels):
        target = total * i / n_levels
        idx = int(np.searchsorted(w_cum, target))
        idx = min(idx, len(s_sorted) - 1)
        cuts.append(int(s_sorted[idx]))
    # enforce strictly non-decreasing
    for i in range(1, len(cuts)):
        cuts[i] = max(cuts[i], cuts[i - 1])
    return cuts


def pias_thresholds(sizes: np.ndarray, n_prios: int = 8) -> list[int]:
    """Sender-side PIAS demotion thresholds (bytes sent so far): equalize
    bytes per level across the size distribution (approximation of PIAS's
    queue-balancing optimization)."""
    sizes = np.asarray(sizes, np.int64)
    return equal_bytes_cutoffs(sizes, sizes.astype(np.float64), n_prios)
