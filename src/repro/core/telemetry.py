"""In-scan telemetry & trace subsystem (DESIGN.md §8).

Every aggregate ``SimResult`` reports is end-of-run; this module adds the
*when* and the *what sequence*: a :class:`TraceConfig` hung off
``SimConfig.trace`` threads bounded accumulators through the existing
``lax.scan`` and post-processes them into a :class:`SimTrace` attached
to the result. Three capture planes, all jit-safe and memory-bounded:

**1. Strided time series.** Every ``stride`` slots (at the *end* of each
window, plus the final slot) the scan snapshots instantaneous queue
occupancy (per-host downlink, per-uplink TOR) and the cumulative
counters (downlink busy/wasted, uplink busy, per-priority-level drained
chunks for both tiers, outstanding-grant backlog per receiver).
Cumulative snapshots diff into exact per-window rates in post-processing
(:meth:`SimTrace.busy_frac`, :meth:`SimTrace.prio_usage` — the paper's
Fig. 13 priority-usage-over-time view), so no division happens in the
scan.

**2. Protocol event ledger.** A fixed-capacity ``(ledger_cap, 5)`` int32
table of ``(slot, kind, msg, host, value)`` rows. Event kinds: grant
issued/raised (``EV_GRANT``), receiver preemption — an incomplete
message evicted from the active grant set (``EV_PREEMPT``), fault chunk
loss per message (``EV_LOSS``), ring-overflow drops (``EV_OVERFLOW``,
msg/host = -1), receiver RESEND and sender-timeout rewinds
(``EV_RESEND`` / ``EV_TIMEOUT``, from the ``faults.apply_recovery``
tap), and message completion (``EV_COMPLETE``). Appends are a masked
cumsum scatter with out-of-bounds drop: once the ledger fills, later
events fall off and ``events_dropped`` counts them — capture stays
jit-safe and bounded no matter how eventful the run is. Rows are
recorded in slot order.

**3. Host wall-clock.** ``TraceConfig(wallclock=True)`` makes
``simulate`` run the scan through the AOT path (``jit.lower`` →
``.compile()`` → execute) and records the exact trace / compile /
execute split in ``SimTrace.timings``; benchmark cells surface the same
split (``benchmarks/roofline.py`` backend cell, ``trace_smoke``).

``SimConfig.trace=None`` (the default) and ``TraceConfig(enabled=False)``
keep the scan free of every array and op defined here: the untraced
program is bit-identical to the committed fabric goldens on both
backends (tests/test_telemetry.py), so the default path pays zero cost.
Under ``run_sweep``'s vmapped batches the full series are reduced to
streaming scalars per run (:meth:`SimTrace.reduce`) so mega-sweeps never
materialize ``(N, T, H)`` histories.

Exporters: :meth:`SimTrace.to_perfetto` (Chrome trace-event JSON,
loadable in https://ui.perfetto.dev), :meth:`SimTrace.to_timeseries_json`
(JSON-safe dict for the bench cache), and ``scripts/export_trace.py``
(CLI around both).
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.protocols import I32, grant_preempted

# ------------------------------------------------------------ event kinds --

EV_GRANT = 0       # receiver granted / raised a message's grant (value=slots)
EV_PREEMPT = 1     # incomplete msg evicted from the active set (value=remain)
EV_LOSS = 2        # fault-injected chunk drops on a message (value=chunks)
EV_OVERFLOW = 3    # ring-overflow drops, either tier (msg=host=-1, value=n)
EV_RESEND = 4      # receiver RESEND rewound the sender (value=chunks)
EV_TIMEOUT = 5     # sender fallback timeout rewound (value=chunks)
EV_COMPLETE = 6    # message completed (value=elapsed slots)

EV_NAMES = {EV_GRANT: "grant", EV_PREEMPT: "preempt", EV_LOSS: "loss",
            EV_OVERFLOW: "overflow", EV_RESEND: "resend",
            EV_TIMEOUT: "timeout", EV_COMPLETE: "complete"}
EV_COLUMNS = ("slot", "kind", "msg", "host", "value")


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Telemetry capture parameters (hashable: rides the jit-static
    ``SimConfig``). ``TraceConfig(enabled=False)`` is the disabled
    sentinel — bit-identical to ``SimConfig.trace=None``."""
    enabled: bool = True
    stride: int = 16                # slots per time-series sample window
    ledger_cap: int = 4096          # event rows kept; 0 disables the ledger
    wallclock: bool = False         # exact AOT trace/compile/execute split
    wallclock_repeats: int = 1      # execute N times, report the min
    #   (best-of-N suppresses shared-machine noise; the scan is
    #   deterministic, so repeats change nothing but the timing)

    def validate(self) -> None:
        if self.stride < 1:
            raise ValueError(f"TraceConfig.stride must be >= 1, got "
                             f"{self.stride}")
        if self.ledger_cap < 0:
            raise ValueError(f"TraceConfig.ledger_cap must be >= 0, got "
                             f"{self.ledger_cap}")
        if self.wallclock_repeats < 1:
            raise ValueError(f"TraceConfig.wallclock_repeats must be "
                             f">= 1, got {self.wallclock_repeats}")


def n_samples(cfg) -> int:
    """Time-series rows for a run: one per full/partial stride window."""
    return -(-cfg.max_slots // cfg.trace.stride)


# ------------------------------------------------------------- scan state --

def init_trace_state(cfg, M: int) -> dict:
    """Telemetry scan state; only trace-enabled configs carry it."""
    tr = cfg.trace
    T, H, P = n_samples(cfg), cfg.n_hosts, cfg.n_prios
    z = lambda shape: jnp.zeros(shape, I32)  # noqa: E731
    st = {
        "tr_q": z((T, H)),           # instantaneous downlink queue (chunks)
        "tr_grant_out": z((T, H)),   # outstanding granted-not-received slots
        "tr_busy": z((T,)),          # cumulative downlink-busy slot count
        "tr_wasted": z((T,)),        # cumulative idle-but-withheld count
        "tr_upbusy": z((T,)),        # cumulative sender-uplink busy count
        "tr_prio": z((T, P)),        # cumulative downlink drains per level
        "tr_active": jnp.zeros((M,), bool),   # last slot's active grant set
    }
    if cfg.fabric_on:
        U = cfg.fabric.n_uplinks_total(cfg.n_hosts)
        st["tr_uq"] = z((T, U))      # instantaneous TOR uplink queues
        st["tr_uprio"] = z((T, P))   # cumulative uplink drains per level
        st["tr_uprio_c"] = z((P,))   # running counter (fabric.uplink_drain)
    if cfg.host_rx_on:
        st["tr_hq"] = z((T, H))      # instantaneous host RX-ring backlog
    if tr.ledger_cap > 0:
        st["tr_ev"] = jnp.full((tr.ledger_cap, 5), -1, I32)
        st["tr_ev_n"] = z(())        # total events SEEN (incl. dropped)
        if cfg.faults_on:
            st["tr_resend"] = z((M,))   # chunks rewound by receiver RESEND
            st["tr_timeout"] = z((M,))  # chunks rewound by sender timeout
    return st


def snapshot(cfg, st) -> dict:
    """Pre-step references needed to difference per-slot event deltas
    (arrays are functional, so this costs nothing)."""
    prev = {"grant_r": st["grant_r"], "completion": st["completion"],
            "lost": st["lost"]}
    if cfg.fabric_on:
        prev["u_lost"] = st["u_lost"]
    if cfg.faults_on:
        prev["msg_lost"] = st["msg_lost"]
    return prev


def _append_events(cfg, st, mask, kind, msg, host, value, now):
    """Masked bulk-append into the fixed ledger: each masked candidate
    takes the next free row; candidates past capacity drop out of bounds
    (``mode="drop"``) and only the seen-counter keeps growing."""
    E = cfg.trace.ledger_cap
    pos = st["tr_ev_n"] + jnp.cumsum(mask.astype(I32)) - mask.astype(I32)
    idx = jnp.where(mask & (pos < E), pos, E)
    rows = jnp.stack([jnp.full_like(kind, now), kind, msg, host, value],
                     axis=1).astype(I32)
    return {**st, "tr_ev": st["tr_ev"].at[idx].set(rows, mode="drop"),
            "tr_ev_n": st["tr_ev_n"] + mask.sum(dtype=I32)}


def _slot_events(cfg, st, S, now, prev, active):
    """Collect this slot's protocol events into the ledger."""
    M = S["size"].shape[0]
    dst, msg_ids = S["dst"], S["msg_ids"]

    def cand(mask, kind, value, msg=msg_ids, host=dst):
        return (mask, jnp.full((mask.shape[0],), kind, I32), msg, host,
                value)

    cands = [
        cand(st["grant_r"] > prev["grant_r"], EV_GRANT, st["grant_r"]),
        cand(grant_preempted(st["tr_active"], active, st["completion"]),
             EV_PREEMPT, jnp.maximum(S["size"] - st["recv"], 0)),
    ]
    if cfg.faults_on:
        lost_d = st["msg_lost"] - prev["msg_lost"]
        cands.append(cand(lost_d > 0, EV_LOSS, lost_d))
        cands.append(cand(st["tr_resend"] > 0, EV_RESEND, st["tr_resend"]))
        cands.append(cand(st["tr_timeout"] > 0, EV_TIMEOUT,
                          st["tr_timeout"]))
    # ring-overflow drops have no message attribution: one scalar row
    over_d = st["lost"] - prev["lost"]
    if cfg.fabric_on:
        over_d = over_d + st["u_lost"] - prev["u_lost"]
    neg1 = jnp.full((1,), -1, I32)
    cands.append(cand((over_d > 0)[None], EV_OVERFLOW, over_d[None],
                      msg=neg1, host=neg1))
    cands.append(cand(st["completion"] == now, EV_COMPLETE,
                      now - S["arrival"] + 1))

    mask = jnp.concatenate([c[0] for c in cands])
    kind = jnp.concatenate([c[1] for c in cands])
    msg = jnp.concatenate([c[2] for c in cands]).astype(I32)
    host = jnp.concatenate([c[3] for c in cands]).astype(I32)
    value = jnp.concatenate([c[4] for c in cands]).astype(I32)
    return _append_events(cfg, st, mask, kind, msg, host, value, now)


def capture_slot(cfg, st, S, now, prev, active, qlen):
    """End-of-slot telemetry hook (called by ``sim.step_fn`` only when
    ``cfg.trace_on``): append this slot's events, then — on window
    boundaries — write one strided time-series row."""
    tr = cfg.trace
    T, H = n_samples(cfg), cfg.n_hosts

    if tr.ledger_cap > 0:
        st = _slot_events(cfg, st, S, now, prev, active)
    st = {**st, "tr_active": active}

    # sample at each window's END (cumulative diffs = exact window rates)
    stride = tr.stride
    do = (now % stride == stride - 1) | (now == cfg.max_slots - 1)
    row = jnp.where(do, now // stride, T)            # OOB drop when idle
    outstanding = jnp.where(st["completion"] < 0,
                            jnp.maximum(st["grant_r"] - st["recv"], 0), 0)
    grant_out = jax.ops.segment_sum(outstanding, S["dst"], num_segments=H)
    upd = {
        "tr_q": st["tr_q"].at[row].set(qlen, mode="drop"),
        "tr_grant_out": st["tr_grant_out"].at[row].set(
            grant_out.astype(I32), mode="drop"),
        "tr_busy": st["tr_busy"].at[row].set(st["busy"].sum(),
                                             mode="drop"),
        "tr_wasted": st["tr_wasted"].at[row].set(st["wasted"].sum(),
                                                 mode="drop"),
        "tr_upbusy": st["tr_upbusy"].at[row].set(st["uplink_busy"].sum(),
                                                 mode="drop"),
        "tr_prio": st["tr_prio"].at[row].set(st["prio_drained"],
                                             mode="drop"),
    }
    if cfg.fabric_on:
        upd["tr_uq"] = st["tr_uq"].at[row].set(
            st["u_valid"].sum(axis=1).astype(I32), mode="drop")
        upd["tr_uprio"] = st["tr_uprio"].at[row].set(st["tr_uprio_c"],
                                                     mode="drop")
    if cfg.host_rx_on:
        upd["tr_hq"] = st["tr_hq"].at[row].set(
            (st["h_rx_tail"] - st["h_rx_head"]).astype(I32), mode="drop")
    return {**st, **upd}


# --------------------------------------------------------------- SimTrace --

@dataclasses.dataclass
class SimTrace:
    """One run's captured telemetry, post-processed to numpy.

    Cumulative series (``*_cum``) snapshot the scan's running counters at
    each sample slot; the windowed accessors difference them into exact
    per-window rates. ``events`` is the ledger's recorded prefix (slot
    order); ``n_events_seen`` counts every event observed including the
    ``events_dropped`` that fell off a full ledger.
    """
    stride: int
    slot_bytes: int
    n_hosts: int
    max_slots: int
    sample_slots: np.ndarray             # (T,) end slot of each window
    q_bytes: np.ndarray                  # (T, H) downlink queue bytes
    grant_out_bytes: np.ndarray          # (T, H) granted-not-received bytes
    busy_cum: np.ndarray                 # (T,) downlink busy slots (all hosts)
    wasted_cum: np.ndarray               # (T,)
    uplink_busy_cum: np.ndarray          # (T,) sender-NIC busy slots
    prio_drained_cum_bytes: np.ndarray   # (T, P) downlink drains per level
    up_q_bytes: np.ndarray | None        # (T, U) TOR uplink queue bytes
    up_prio_drained_cum_bytes: np.ndarray | None   # (T, P)
    events: np.ndarray                   # (n, 5) int32, EV_COLUMNS order
    ledger_cap: int
    n_events_seen: int
    timings: dict | None = None          # wallclock=True: AOT stage split
    host_rx_q_chunks: np.ndarray | None = None   # (T, H) host RX backlog

    # ------------------------------------------------------------ derived

    @property
    def n_events(self) -> int:
        return int(self.events.shape[0])

    @property
    def events_dropped(self) -> int:
        return max(0, self.n_events_seen - self.n_events)

    def _widths(self) -> np.ndarray:
        return np.diff(self.sample_slots, prepend=-1)

    def busy_frac(self) -> np.ndarray:
        """(T,) windowed downlink busy fraction (all hosts pooled)."""
        return np.diff(self.busy_cum, prepend=0) \
            / (self._widths() * self.n_hosts)

    def wasted_frac(self) -> np.ndarray:
        return np.diff(self.wasted_cum, prepend=0) \
            / (self._widths() * self.n_hosts)

    def uplink_busy_frac(self) -> np.ndarray:
        return np.diff(self.uplink_busy_cum, prepend=0) \
            / (self._widths() * self.n_hosts)

    def prio_usage(self, tier: str = "down") -> np.ndarray:
        """(T, P) per-window drained bytes per priority level — the
        Fig. 13 view. ``tier`` is "down" or (fabric runs) "up"."""
        cum = self.prio_drained_cum_bytes if tier == "down" \
            else self.up_prio_drained_cum_bytes
        if cum is None:
            raise ValueError(f"no {tier!r}-tier priority series captured")
        return np.diff(cum, prepend=0, axis=0)

    def events_of(self, kind: int) -> np.ndarray:
        return self.events[self.events[:, 1] == kind]

    # ------------------------------------------------------------ reduce

    def reduce(self) -> dict:
        """Streaming-stat scalars (the only thing vmapped sweeps keep)."""
        return {
            "stride": self.stride,
            "samples": int(len(self.sample_slots)),
            "n_events": self.n_events,
            "n_events_seen": int(self.n_events_seen),
            "events_dropped": self.events_dropped,
            "ledger_cap": self.ledger_cap,
            "q_peak_bytes": int(self.q_bytes.max()) if self.q_bytes.size
            else 0,
            "grant_out_peak_bytes": int(self.grant_out_bytes.max())
            if self.grant_out_bytes.size else 0,
            "up_q_peak_bytes": int(self.up_q_bytes.max())
            if self.up_q_bytes is not None and self.up_q_bytes.size else None,
            "host_rx_q_peak_chunks": int(self.host_rx_q_chunks.max())
            if self.host_rx_q_chunks is not None
            and self.host_rx_q_chunks.size else None,
            "timings": self.timings,
        }

    # --------------------------------------------------------- exporters

    def to_timeseries_json(self) -> dict:
        """JSON-safe time-series dict (the bench-cache form)."""
        out = {
            "stride": self.stride, "slot_bytes": self.slot_bytes,
            "n_hosts": self.n_hosts, "max_slots": self.max_slots,
            "sample_slots": self.sample_slots.tolist(),
            "q_bytes": self.q_bytes.tolist(),
            "grant_out_bytes": self.grant_out_bytes.tolist(),
            "busy_frac": np.round(self.busy_frac(), 6).tolist(),
            "wasted_frac": np.round(self.wasted_frac(), 6).tolist(),
            "uplink_busy_frac":
                np.round(self.uplink_busy_frac(), 6).tolist(),
            "prio_drained_bytes": self.prio_usage("down").tolist(),
            "events": {"columns": list(EV_COLUMNS),
                       "rows": self.events.tolist(),
                       "kinds": {v: k for k, v in EV_NAMES.items()},
                       "n_seen": int(self.n_events_seen),
                       "dropped": self.events_dropped},
            "timings": self.timings,
        }
        if self.up_q_bytes is not None:
            out["up_q_bytes"] = self.up_q_bytes.tolist()
            out["up_prio_drained_bytes"] = self.prio_usage("up").tolist()
        if self.host_rx_q_chunks is not None:
            out["host_rx_q_chunks"] = self.host_rx_q_chunks.tolist()
        return out

    def to_perfetto(self, path=None) -> dict:
        """Chrome trace-event / Perfetto JSON. One slot maps to one
        microsecond of trace time. Counter tracks carry the strided
        series; ledger rows become instant events on per-host tracks;
        completions additionally become duration ("X") slices spanning
        arrival→completion. Load at https://ui.perfetto.dev."""
        ev: list[dict] = []

        def meta(pid, name):
            ev.append({"ph": "M", "pid": pid, "tid": 0,
                       "name": "process_name", "args": {"name": name}})

        meta(0, "time series")
        meta(1, "protocol events")
        meta(2, "messages")

        P = self.prio_drained_cum_bytes.shape[1]
        prio = self.prio_usage("down")
        for k, t in enumerate(self.sample_slots.tolist()):
            ev.append({"ph": "C", "pid": 0, "tid": 0, "ts": t,
                       "name": "downlink_q_bytes",
                       "args": {f"h{h}": int(self.q_bytes[k, h])
                                for h in range(self.n_hosts)}})
            ev.append({"ph": "C", "pid": 0, "tid": 0, "ts": t,
                       "name": "grant_outstanding_bytes",
                       "args": {f"h{h}": int(self.grant_out_bytes[k, h])
                                for h in range(self.n_hosts)}})
            ev.append({"ph": "C", "pid": 0, "tid": 0, "ts": t,
                       "name": "prio_drained_bytes",
                       "args": {f"p{p}": int(prio[k, p])
                                for p in range(P)}})
            if self.up_q_bytes is not None:
                ev.append({"ph": "C", "pid": 0, "tid": 0, "ts": t,
                           "name": "tor_uplink_q_bytes",
                           "args": {f"u{u}": int(self.up_q_bytes[k, u])
                                    for u in
                                    range(self.up_q_bytes.shape[1])}})
            if self.host_rx_q_chunks is not None:
                ev.append({"ph": "C", "pid": 0, "tid": 0, "ts": t,
                           "name": "host_rx_q_chunks",
                           "args":
                           {f"h{h}": int(self.host_rx_q_chunks[k, h])
                            for h in range(self.n_hosts)}})

        for slot, kind, msg, host, value in self.events.tolist():
            ev.append({"ph": "i", "s": "t", "pid": 1,
                       "tid": int(max(host, 0)), "ts": int(slot),
                       "name": EV_NAMES.get(int(kind), f"kind{kind}"),
                       "args": {"msg": int(msg), "value": int(value)}})
            if kind == EV_COMPLETE:
                ev.append({"ph": "X", "pid": 2, "tid": int(max(host, 0)),
                           "ts": int(slot) - int(value) + 1,
                           "dur": int(value), "name": f"msg{int(msg)}",
                           "args": {"elapsed_slots": int(value)}})

        doc = {"displayTimeUnit": "ms", "traceEvents": ev,
               "otherData": {"slot_bytes": self.slot_bytes,
                             "stride": self.stride,
                             "events_dropped": self.events_dropped}}
        if path is not None:
            from pathlib import Path
            Path(path).write_text(json.dumps(doc))
        return doc


def finalize_trace(cfg, st: dict, timings: dict | None = None) -> SimTrace:
    """Build a :class:`SimTrace` from one run's (numpy) final scan state."""
    tr = cfg.trace
    T = n_samples(cfg)
    sb = cfg.slot_bytes
    sample_slots = np.minimum(np.arange(1, T + 1) * tr.stride - 1,
                              cfg.max_slots - 1).astype(np.int64)
    if tr.ledger_cap > 0:
        seen = int(st["tr_ev_n"])
        n = min(seen, tr.ledger_cap)
        events = np.asarray(st["tr_ev"][:n]).astype(np.int32)
    else:
        seen = 0
        events = np.zeros((0, 5), np.int32)
    return SimTrace(
        stride=tr.stride, slot_bytes=sb, n_hosts=cfg.n_hosts,
        max_slots=cfg.max_slots, sample_slots=sample_slots,
        q_bytes=np.asarray(st["tr_q"]) * sb,
        grant_out_bytes=np.asarray(st["tr_grant_out"]) * sb,
        busy_cum=np.asarray(st["tr_busy"]),
        wasted_cum=np.asarray(st["tr_wasted"]),
        uplink_busy_cum=np.asarray(st["tr_upbusy"]),
        prio_drained_cum_bytes=np.asarray(st["tr_prio"]) * sb,
        up_q_bytes=np.asarray(st["tr_uq"]) * sb if cfg.fabric_on else None,
        up_prio_drained_cum_bytes=np.asarray(st["tr_uprio"]) * sb
        if cfg.fabric_on else None,
        events=events, ledger_cap=tr.ledger_cap, n_events_seen=seen,
        timings=timings,
        host_rx_q_chunks=np.asarray(st["tr_hq"]) if cfg.host_rx_on
        else None,
    )


def reduce_state(cfg, st: dict) -> dict:
    """Device-side trace reduction for streaming sweeps (DESIGN.md §9):
    the :meth:`SimTrace.reduce` peaks/counts computed INSIDE the compiled
    program, so sharded mega-sweeps gather a handful of trace scalars per
    run instead of the ``(T, H)`` series. Works unchanged under chunked
    scans — the strided rows are written by global slot index, so the
    series (and therefore its max) is identical to the flat scan's."""
    out = {"tr_q_peak": st["tr_q"].max(),
           "tr_go_peak": st["tr_grant_out"].max()}
    if cfg.fabric_on:
        out["tr_uq_peak"] = st["tr_uq"].max()
    if cfg.host_rx_on:
        out["tr_hq_peak"] = st["tr_hq"].max()
    if cfg.ledger_on:
        out["tr_ev_seen"] = st["tr_ev_n"]
    return out


# ------------------------------------------------------------- wall clock --

def timed_aot_run(jit_fn, all_args: tuple, dynamic_args: tuple,
                  repeats: int = 1) -> tuple[Any, dict]:
    """Run a jitted function through the AOT path and return
    ``(result, timings)`` with the exact trace / compile / execute split
    in seconds. ``all_args`` is the full positional argument list (as
    the jitted function would be called); ``dynamic_args`` are the
    non-static subset, in order, passed again at execute.
    ``repeats > 1`` executes the compiled program N times and reports
    the MINIMUM execute time (best-of-N: robust to machine noise; only
    meaningful for deterministic functions)."""
    t0 = time.perf_counter()
    lowered = jit_fn.lower(*all_args)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()
    execs = []
    for _ in range(max(repeats, 1)):
        te = time.perf_counter()
        out = compiled(*dynamic_args)
        jax.block_until_ready(out)
        execs.append(time.perf_counter() - te)
    return out, {"trace_s": round(t1 - t0, 4),
                 "compile_s": round(t2 - t1, 4),
                 "execute_s": round(min(execs), 4),
                 "execute_repeats": len(execs)}


__all__ = ["TraceConfig", "SimTrace", "init_trace_state", "snapshot",
           "capture_slot", "finalize_trace", "reduce_state",
           "timed_aot_run", "n_samples",
           "EV_GRANT", "EV_PREEMPT", "EV_LOSS", "EV_OVERFLOW", "EV_RESEND",
           "EV_TIMEOUT", "EV_COMPLETE", "EV_NAMES", "EV_COLUMNS"]
