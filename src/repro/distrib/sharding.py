"""Sharding rules: logical axes -> physical mesh axes, per architecture.

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
Logical axes used by ParamDefs: "tp" (tensor), "fsdp" (ZeRO-3-style param
shard), "ep" (experts), "stack" (scanned layer dim, never sharded), "sp"
(sequence parallel, activations only).

A dimension is only sharded when divisible (see params._resolve_axis), so
small models degrade gracefully to replication.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model as M
from repro.models.params import param_specs

FSDP_MIN_PARAMS = 6e9   # below this, parameters are replicated across "data"


def mesh_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def sharding_rules(cfg: ModelConfig, sizes: dict[str, int],
                   *, force_fsdp: bool | None = None) -> dict[str, tuple[str, ...]]:
    n = M.count_model_params(cfg)
    use_fsdp = force_fsdp if force_fsdp is not None else n >= FSDP_MIN_PARAMS
    fsdp_axes = tuple(a for a in ("pod", "data") if a in sizes)
    mdl = ("model",) if "model" in sizes else ()
    return {
        "tp": mdl,
        # fallback: if the primary tp dim (heads) isn't divisible, the next
        # tagged dim (head_dim / expert ff) takes the model axis instead —
        # param_specs drops duplicate axis uses, so exactly one wins.
        "tp2": mdl,
        "ep": mdl,
        "fsdp": fsdp_axes if use_fsdp else (),
        "stack": (),
        "sp": mdl,
    }


def batch_axes(sizes: dict[str, int], global_batch: int):
    """Mesh axes to shard the batch over (largest divisible prefix of
    (pod, data), optionally extended by model for pure-DP small models)."""
    axes = [a for a in ("pod", "data") if a in sizes]
    total = 1
    used = []
    for a in axes:
        if global_batch % (total * sizes[a]) == 0:
            used.append(a)
            total *= sizes[a]
    return tuple(used)


def model_param_specs(cfg: ModelConfig, mesh: Mesh, **kw):
    sizes = mesh_sizes(mesh)
    rules = sharding_rules(cfg, sizes, **kw)
    return param_specs(M.model_defs(cfg), rules, sizes)


def activation_shardings(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig,
                         *, sequence_parallel: bool | None = None,
                         grad_accum: int = 1):
    """Specs for with_sharding_constraint hooks inside the model."""
    sizes = mesh_sizes(mesh)
    bax = batch_axes(sizes, shape.global_batch)
    n = M.count_model_params(cfg)
    if sequence_parallel is None:
        # SP pays off when activations dominate: long sequences / big d_model
        sequence_parallel = (shape.seq_len * cfg.d_model >= 4096 * 4096
                             and not shape.is_decode)
    seq_ax = "model" if (sequence_parallel and "model" in sizes
                         and shape.seq_len % sizes["model"] == 0) else None
    bspec = bax if bax else None
    mdl = "model" if "model" in sizes else None
    # logits: prefer vocab sharding; under sequence parallelism the seq dim
    # already takes "model", so the vocab dim must stay unsharded.
    logits_spec = P(bspec, seq_ax, None) if seq_ax else P(bspec, None, mdl)
    moe_spec = None
    if (cfg.num_experts and "model" in sizes
            and cfg.num_experts % sizes["model"] == 0):
        # (E, C, D): experts over model AND capacity rows over data — E-only
        # sharding leaves every device holding all tokens' dispatch rows
        # (measured: no flops change vs the unconstrained baseline); 2-D
        # sharding keeps tokens data-parallel through the expert matmuls.
        dax = tuple(a for a in ("pod", "data") if a in sizes)
        moe_spec = P("model", dax if dax else None, None)
    # heads not divisible by tp: sharding head_dim instead makes the score
    # einsums contract a sharded dim (all-reduce per KV block per layer —
    # measured 19.4 GB/layer on llama3.2-3b). Fallback: run the attention
    # region data-parallel over BOTH axes (batch divisible by data*model).
    import math as _m
    attn_spec = None
    if cfg.num_heads and "model" in sizes \
            and cfg.num_heads % sizes["model"] != 0 and not shape.is_decode:
        full = _m.prod(sizes.values())
        # must divide the MICROBATCH, not the global batch — otherwise GSPMD
        # pads the attention region (measured: 5x flops inflation on 3B)
        if (shape.global_batch // max(grad_accum, 1)) % full == 0:
            attn_spec = P(tuple(sizes.keys()), None, None, None)
    return {
        "residual": P(bspec, seq_ax, None),
        "kv_cache": P(bspec, mdl, None, None),
        "logits": logits_spec,
        "moe_dispatch": moe_spec,
        "attn_qkv": attn_spec,
    }


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def cache_specs(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig):
    """PartitionSpec pytree matching model.cache_shapes: batch over data
    axes, cache sequence dim over model (distributed decode attention).
    Any axis whose size isn't divisible by its mesh axes is replicated."""
    import math
    sizes = mesh_sizes(mesh)
    bax = batch_axes(sizes, shape.global_batch)
    mdl = "model" if "model" in sizes else None

    shapes = M.cache_shapes(cfg, shape.global_batch, shape.seq_len)

    def fit(axis, dim):
        if axis is None:
            return None
        names = (axis,) if isinstance(axis, str) else tuple(axis)
        if not names:
            return None
        n = math.prod(sizes[a] for a in names)
        return axis if (n > 1 and dim % n == 0) else None

    def spec_for(nm, shp):
        nd = len(shp)
        bspec = bax if bax else None
        if nm in ("k", "v", "xk", "xv"):          # (B, S, KV, hd) [+nb]
            want = [bspec, mdl, None, None]
        elif nm in ("ckv", "kr"):                  # (B, S, R) [+nb]
            want = [bspec, mdl, None]
        elif nm == "state":                        # (B, H, P, N) [+nb]
            want = [bspec, mdl, None, None]
        elif nm == "conv":                         # (B, W-1, C) [+nb]
            want = [bspec, None, None]
        else:
            want = [None] * nd
        if nd == len(want) + 1:
            want = [None] + want                   # stacked over blocks
        return P(*[fit(a, d) for a, d in zip(want, shp)])

    def walk(tree):
        if isinstance(tree, dict):
            return {k: (walk(v) if isinstance(v, dict) else spec_for(k, v))
                    for k, v in tree.items()}
        return tree

    return walk(shapes)


def check_divisibility(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig) -> list[str]:
    """Human-readable notes on what falls back to replication."""
    sizes = mesh_sizes(mesh)
    notes = []
    tp = sizes.get("model", 1)
    if cfg.num_heads and cfg.num_heads % tp:
        notes.append(f"attn heads {cfg.num_heads} replicated (tp={tp})")
    if cfg.num_experts and cfg.num_experts % tp:
        notes.append(f"experts {cfg.num_experts} TP-sharded on d_ff instead of EP")
    if cfg.ssm_state_dim and M.n_scan_blocks(cfg) and cfg.ssm_num_heads % tp:
        notes.append(f"ssm heads {cfg.ssm_num_heads} replicated (tp={tp})")
    bax = batch_axes(sizes, shape.global_batch)
    import math
    got = math.prod(sizes[a] for a in bax) if bax else 1
    if not bax:
        notes.append(f"batch {shape.global_batch} unshardable -> replicated")
    return notes
