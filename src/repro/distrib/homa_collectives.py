"""Homa-inspired gradient-sync scheduling (DESIGN.md §2.2).

What transfers from the paper to XLA collectives:

- **Message orientation** (paper §3.1): gradients are synced as independent
  size-bounded *chunks*, never as one fused mega-collective, so a small
  late-arriving tensor is not head-of-line blocked behind hundreds of MB
  (the paper's InfRC-MC experiment: 100x tail win from killing HoL).
- **SRPT issue order** (§3.2): chunks are issued shortest-remaining-first;
  short dependency chains retire first, overlapping the long tail.
- **Controlled overcommitment** (§3.5): at most K chunk-collectives are
  structurally in flight. We encode this as K dependency "lanes": within a
  lane, chunk i+1 consumes an optimization_barrier on chunk i's result, so
  the XLA scheduler cannot hoist more than K collectives concurrently. One
  stalled lane leaves K-1 lanes of work (the paper's "unresponsive sender"
  insurance), while live-buffer usage stays bounded at K chunks.

What does NOT transfer: in-network priority queues (no ICI analogue) —
priority == position in the issue schedule. See DESIGN.md §2.3.

Also provides int8 gradient compression with error feedback, composed with
the chunk scheduler (compressed chunks move as int8 on the wire via
all_gather + local reduction, so HLO collective bytes reflect the 4x/2x
saving).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class SyncConfig:
    chunk_bytes: int = 4 << 20          # 4 MB chunks (RTTbytes analogue)
    overcommit: int = 7                 # K lanes (paper: # sched priorities)
    srpt: bool = True                   # shortest-first issue order
    compress: str | None = None         # None | "int8"
    error_feedback: bool = True


@dataclasses.dataclass(frozen=True)
class Chunk:
    leaf: int            # flat leaf index
    start: int           # element offset
    size: int            # element count
    bytes: int
    remaining: int       # bytes remaining in this leaf incl. this chunk (SRPT key)


def chunk_plan(shapes: list[tuple[tuple[int, ...], Any]],
               cfg: SyncConfig) -> list[Chunk]:
    """Static chunking + SRPT schedule over grad leaves.

    SRPT key: bytes remaining in the leaf at the time this chunk would be
    sent — mirrors Homa's remaining-bytes priority, so all of a small
    tensor beats the tail of a big one, and a big tensor's last chunks rise
    in priority as it completes."""
    chunks: list[Chunk] = []
    for i, (shape, dtype) in enumerate(shapes):
        n = int(np.prod(shape)) if shape else 1
        isz = jnp.dtype(dtype).itemsize
        per = max(cfg.chunk_bytes // isz, 1)
        total_b = n * isz
        off = 0
        while off < n:
            size = min(per, n - off)
            chunks.append(Chunk(i, off, size, size * isz,
                                remaining=total_b - off * isz))
            off += size
    if cfg.srpt:
        chunks.sort(key=lambda c: (c.remaining, c.leaf, c.start))
    return chunks


def _quantize(x, err):
    xf = x.astype(F32) + (err if err is not None else 0.0)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(F32) * scale
    new_err = xf - deq
    return q, scale, new_err


def homa_allreduce(grads, axis_name: str, cfg: SyncConfig,
                   err_state=None):
    """Mean-allreduce a grad pytree over `axis_name` inside shard_map, with
    chunked SRPT-ordered collectives in K bounded lanes.

    Returns (synced_grads, new_err_state)."""
    leaves, treedef = jax.tree.flatten(grads)
    shapes = [(l.shape, l.dtype) for l in leaves]
    plan = chunk_plan(shapes, cfg)
    flat = [l.reshape(-1) for l in leaves]
    err_flat = (jax.tree.leaves(err_state) if err_state is not None
                else [None] * len(leaves))
    nshards = lax.axis_size(axis_name)

    out = [jnp.zeros_like(f, F32) for f in flat]
    new_err = [jnp.zeros_like(f, F32) if cfg.compress and cfg.error_feedback
               else None for f in flat]

    K = max(cfg.overcommit, 1)
    lane_tokens: list[Any] = [None] * K   # dependency chain per lane

    for idx, ch in enumerate(plan):
        lane = idx % K
        piece = lax.dynamic_slice(flat[ch.leaf], (ch.start,), (ch.size,))
        tok = lane_tokens[lane]
        if tok is not None:
            # structural dependency: this chunk cannot issue before the
            # previous chunk in its lane completed (bounded overcommitment)
            piece, _ = lax.optimization_barrier((piece, tok))
        if cfg.compress == "int8":
            e = (lax.dynamic_slice(err_flat[ch.leaf], (ch.start,), (ch.size,))
                 if (err_flat[ch.leaf] is not None) else None)
            q, scale, e_new = _quantize(piece, e)
            # int8 on the wire: all_gather int8 + local reduce
            qg = lax.all_gather(q, axis_name)                # (n, size) int8
            sg = lax.all_gather(scale, axis_name)            # (n,)
            red = jnp.sum(qg.astype(F32) * sg[:, None], axis=0) / nshards
            if cfg.error_feedback:
                new_err[ch.leaf] = lax.dynamic_update_slice(
                    new_err[ch.leaf], e_new, (ch.start,))
        else:
            red = lax.psum(piece.astype(F32), axis_name) / nshards
        out[ch.leaf] = lax.dynamic_update_slice(out[ch.leaf], red,
                                                (ch.start,))
        lane_tokens[lane] = red

    synced = [o.reshape(l.shape).astype(l.dtype)
              for o, l in zip(out, leaves)]
    err_out = (jax.tree.unflatten(treedef, new_err)
               if cfg.compress and cfg.error_feedback else None)
    return jax.tree.unflatten(treedef, synced), err_out


def naive_allreduce(grads, axis_name: str):
    """Baseline: one fused psum per leaf, descending size (the 'streaming'
    anti-pattern the paper argues against)."""
    n = lax.axis_size(axis_name)
    return jax.tree.map(lambda g: lax.psum(g.astype(F32), axis_name) / n,
                        grads)


def build_dp_train_step(loss_fn: Callable, opt_update: Callable, mesh,
                        cfg: SyncConfig | None = None, axis: str = "data"):
    """Pure-data-parallel train step with explicit Homa-scheduled grad sync.

    params replicated; batch sharded over `axis`. loss_fn(params, batch) ->
    scalar. opt_update(params, grads, opt_state) -> (params, opt_state,
    metrics). Returns a jit'd step(params, opt_state, batch, err_state)."""
    from jax.sharding import PartitionSpec as P
    cfg = cfg or SyncConfig()

    @partial(jax.shard_map, mesh=mesh,
             in_specs=(P(), P(), P(axis), P()),
             out_specs=(P(), P(), P(), P()),
             check_vma=False)
    def step(params, opt_state, batch, err_state):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        loss = lax.pmean(loss, axis)
        grads, err_state = homa_allreduce(grads, axis, cfg, err_state)
        params, opt_state, metrics = opt_update(params, grads, opt_state)
        metrics = {**metrics, "loss": loss}
        if err_state is None:
            err_state = jnp.zeros((), F32)
        return params, opt_state, metrics, err_state

    return jax.jit(step)


def init_err_state(params, cfg: SyncConfig):
    if cfg.compress and cfg.error_feedback:
        return jax.tree.map(
            lambda p: jnp.zeros((int(np.prod(p.shape)),), F32), params)
    return jnp.zeros((), F32)
