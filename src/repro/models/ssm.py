"""Mamba2 (SSD — state-space duality) block in pure JAX.

The chunked dual form: within a chunk the recurrence is evaluated as a masked
quadratic (attention-like) product; across chunks a small per-head state
(P x N) is carried by a scan. This is the portable XLA path; the Pallas TPU
kernel in ``repro.kernels.ssd`` implements the same algorithm with explicit
VMEM tiling and is validated against ``repro.kernels.ssd.ref``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models.params import ParamDef
from repro.models.layers import rmsnorm

F32 = jnp.float32


def ssm_defs(cfg: ModelConfig):
    D, din = cfg.d_model, cfg.d_inner
    H, P, N, W = cfg.ssm_num_heads, cfg.ssm_head_dim, cfg.ssm_state_dim, cfg.ssm_conv_width
    return {
        "w_z": ParamDef((D, H, P), ("fsdp", "tp", "tp2"), init="scaled", fan_in=D),
        "w_x": ParamDef((D, H, P), ("fsdp", "tp", "tp2"), init="scaled", fan_in=D),
        "w_B": ParamDef((D, N), ("fsdp", None), init="scaled", fan_in=D),
        "w_C": ParamDef((D, N), ("fsdp", None), init="scaled", fan_in=D),
        "w_dt": ParamDef((D, H), ("fsdp", "tp"), init="scaled", fan_in=D),
        "dt_bias": ParamDef((H,), ("tp",), init="zeros"),
        "A_log": ParamDef((H,), ("tp",), init="zeros"),       # A = -exp(A_log)
        "D_skip": ParamDef((H,), ("tp",), init="ones"),
        "conv_x": ParamDef((W, H, P), (None, "tp", "tp2"), init="scaled", fan_in=W),
        "conv_B": ParamDef((W, N), (None, None), init="scaled", fan_in=W),
        "conv_C": ParamDef((W, N), (None, None), init="scaled", fan_in=W),
        "norm": ParamDef((H, P), ("tp", "tp2"), init="ones"),
        "w_out": ParamDef((H, P, D), ("tp", "tp2", "fsdp"), init="scaled", fan_in=din),
    }


def _causal_conv(x, kernel):
    """Depthwise causal conv. x: (B, S, C...), kernel: (W, C...)."""
    W = kernel.shape[0]
    pads = [(0, 0), (W - 1, 0)] + [(0, 0)] * (x.ndim - 2)
    xp = jnp.pad(x, pads)
    out = sum(xp[:, i:i + x.shape[1]] * kernel[i] for i in range(W))
    return out


def segsum_decay(dA):
    """dA: (..., L) -> decay matrix exp(cumsum_i - cumsum_j) lower-triangular.
    Returns (..., L, L) in f32, zero above diagonal."""
    L = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(mask, jnp.exp(diff), 0.0)


def ssd_chunked(x, dt, A, B, C, chunk: int):
    """SSD forward. x: (b,s,h,p) dt: (b,s,h) A: (h,) B,C: (b,s,n).
    Returns y: (b,s,h,p) f32 and final state (b,h,p,n)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    S = s + pad
    nc = S // chunk
    xc = x.reshape(b, nc, chunk, h, p).astype(F32)
    dtc = dt.reshape(b, nc, chunk, h).astype(F32)
    Bc = B.reshape(b, nc, chunk, n).astype(F32)
    Cc = C.reshape(b, nc, chunk, n).astype(F32)

    dA = dtc * A.astype(F32)                                  # (b,nc,l,h)
    dA_h = dA.transpose(0, 1, 3, 2)                           # (b,nc,h,l)
    cums = jnp.cumsum(dA_h, axis=-1)                          # (b,nc,h,l)

    # ---- intra-chunk (quadratic) term
    Lmat = segsum_decay(dA_h)                                 # (b,nc,h,l,l)
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)                # (b,nc,l,l)
    att = cb[:, :, None] * Lmat * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", att, xc)

    # ---- per-chunk input -> state
    decay_to_end = jnp.exp(cums[..., -1:] - cums)             # (b,nc,h,l)
    sx = xc * (dtc * decay_to_end.transpose(0, 1, 3, 2))[..., None]
    states = jnp.einsum("bcln,bclhp->bchpn", Bc, sx)          # (b,nc,h,p,n)

    # ---- inter-chunk recurrence
    chunk_decay = jnp.exp(cums[..., -1])                      # (b,nc,h)

    def step(carry, inp):
        st_in = carry                                         # (b,h,p,n)
        dec, add = inp
        st_out = st_in * dec[..., None, None] + add
        return st_out, st_in                                  # emit state *before* chunk

    init = jnp.zeros((b, h, p, n), F32)
    final, st_before = lax.scan(
        step, init,
        (chunk_decay.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)))
    st_before = st_before.transpose(1, 0, 2, 3, 4)            # (b,nc,h,p,n)

    y_inter = jnp.einsum("bcln,bchpn,bclh->bclhp", Cc, st_before,
                         jnp.exp(cums).transpose(0, 1, 3, 2))
    y = (y_intra + y_inter).reshape(b, S, h, p)[:, :s]
    return y, final


def ssd_decode_step(state, x, dt, A, B, C):
    """One-token SSD update. state: (b,h,p,n); x: (b,h,p); dt: (b,h);
    B,C: (b,n). Returns (y (b,h,p), new_state)."""
    dA = jnp.exp(dt.astype(F32) * A.astype(F32))              # (b,h)
    dBx = jnp.einsum("bn,bhp->bhpn", B.astype(F32),
                     x.astype(F32) * dt.astype(F32)[..., None])
    new_state = state * dA[..., None, None] + dBx
    y = jnp.einsum("bhpn,bn->bhp", new_state, C.astype(F32))
    return y, new_state


def mamba_block(cfg: ModelConfig, p, x, *, use_kernel: bool = False):
    """Full-sequence Mamba2 mixer. x: (B, S, D) ->
    (out, (final_state, conv_tail)) where conv_tail holds the last W-1
    pre-conv features (for decode continuation)."""
    Bsz, S, D = x.shape
    H, P, N, W = cfg.ssm_num_heads, cfg.ssm_head_dim, cfg.ssm_state_dim, cfg.ssm_conv_width
    z = jnp.einsum("bsd,dhp->bshp", x, p["w_z"], preferred_element_type=F32)
    xin = jnp.einsum("bsd,dhp->bshp", x, p["w_x"],
                     preferred_element_type=F32).astype(x.dtype)
    Bv = jnp.einsum("bsd,dn->bsn", x, p["w_B"],
                    preferred_element_type=F32).astype(x.dtype)
    Cv = jnp.einsum("bsd,dn->bsn", x, p["w_C"],
                    preferred_element_type=F32).astype(x.dtype)
    dt = jnp.einsum("bsd,dh->bsh", x, p["w_dt"], preferred_element_type=F32)
    dt = jax.nn.softplus(dt + p["dt_bias"].astype(F32))

    # pre-conv features for the decode conv ring (last W-1 steps)
    pre = jnp.concatenate([xin.reshape(Bsz, S, H * P), Bv, Cv], -1)
    conv_tail = pre[:, -(W - 1):] if S >= W - 1 else jnp.pad(
        pre, ((0, 0), (W - 1 - S, 0), (0, 0)))

    xin = jax.nn.silu(_causal_conv(xin, p["conv_x"]).astype(F32)).astype(x.dtype)
    Bv = jax.nn.silu(_causal_conv(Bv, p["conv_B"]).astype(F32)).astype(x.dtype)
    Cv = jax.nn.silu(_causal_conv(Cv, p["conv_C"]).astype(F32)).astype(x.dtype)

    A = -jnp.exp(p["A_log"].astype(F32))
    if use_kernel:
        from repro.kernels.ssd import ops as ssd_ops
        y, final = ssd_ops.ssd(xin, dt, A, Bv, Cv, chunk=cfg.ssm_chunk)
    else:
        y, final = ssd_chunked(xin, dt, A, Bv, Cv, cfg.ssm_chunk)
    y = y + p["D_skip"].astype(F32)[None, None, :, None] * xin.astype(F32)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y.astype(x.dtype), p["norm"])
    out = jnp.einsum("bshp,hpd->bsd", y, p["w_out"], preferred_element_type=F32)
    return out.astype(x.dtype), (final, conv_tail)


def mamba_block_decode(cfg: ModelConfig, p, x, cache):
    """One-token Mamba2 step. x: (B, 1, D);
    cache: {'state': (B,H,P,N), 'conv': (B, W-1, H*P + 2N)}."""
    Bsz, _, D = x.shape
    H, P, N, W = cfg.ssm_num_heads, cfg.ssm_head_dim, cfg.ssm_state_dim, cfg.ssm_conv_width
    xt = x[:, 0]
    z = jnp.einsum("bd,dhp->bhp", xt, p["w_z"], preferred_element_type=F32)
    xin = jnp.einsum("bd,dhp->bhp", xt, p["w_x"], preferred_element_type=F32)
    Bv = jnp.einsum("bd,dn->bn", xt, p["w_B"], preferred_element_type=F32)
    Cv = jnp.einsum("bd,dn->bn", xt, p["w_C"], preferred_element_type=F32)
    dt = jnp.einsum("bd,dh->bh", xt, p["w_dt"], preferred_element_type=F32)
    dt = jax.nn.softplus(dt + p["dt_bias"].astype(F32))

    # conv ring: cache['conv'] holds the last W-1 pre-conv features
    feat = jnp.concatenate([xin.reshape(Bsz, H * P), Bv, Cv], -1)  # (B, HP+2N)
    hist = jnp.concatenate([cache["conv"], feat[:, None, :]], 1)   # (B, W, .)
    kx = p["conv_x"].reshape(W, H * P).astype(F32)
    kB = p["conv_B"].astype(F32)
    kC = p["conv_C"].astype(F32)
    xc = jnp.einsum("bwc,wc->bc", hist[..., :H * P].astype(F32), kx)
    Bc = jnp.einsum("bwc,wc->bc", hist[..., H * P:H * P + N].astype(F32), kB)
    Cc = jnp.einsum("bwc,wc->bc", hist[..., H * P + N:].astype(F32), kC)
    xc = jax.nn.silu(xc).reshape(Bsz, H, P)
    Bc, Cc = jax.nn.silu(Bc), jax.nn.silu(Cc)

    A = -jnp.exp(p["A_log"].astype(F32))
    y, new_state = ssd_decode_step(cache["state"].astype(F32), xc, dt, A, Bc, Cc)
    y = y + p["D_skip"].astype(F32)[None, :, None] * xc
    y = y * jax.nn.silu(z)
    y = rmsnorm(y.astype(x.dtype), p["norm"])
    out = jnp.einsum("bhp,hpd->bd", y, p["w_out"], preferred_element_type=F32)
    new_cache = {"state": new_state.astype(cache["state"].dtype),
                 "conv": hist[:, 1:].astype(cache["conv"].dtype)}
    return out[:, None, :].astype(x.dtype), new_cache


def ssm_cache_shape(cfg: ModelConfig, batch: int):
    H, P, N, W = cfg.ssm_num_heads, cfg.ssm_head_dim, cfg.ssm_state_dim, cfg.ssm_conv_width
    return {"state": (batch, H, P, N), "conv": (batch, W - 1, H * P + 2 * N)}
