"""Model layers: norms, RoPE, attention (GQA / MLA / cross / sliding-window),
MLP, and MoE with sort-based capacity dispatch.

Conventions
-----------
- Activations: (B, S, D). Attention heads explicit: q (B, S, H, hd).
- All matmuls in bf16 with f32 accumulation (`preferred_element_type`).
- Softmax / norm statistics in f32.
- Train/prefill attention is *blockwise* (online-softmax scan over KV blocks)
  so no (S, S) score tensor is ever materialized — this mirrors the Pallas
  kernel in ``repro.kernels.attention`` and is the portable XLA path.
- Decode reads a seq-sharded KV cache; softmax reductions over the sharded
  axis are handled by the SPMD partitioner (all-reduce of max/sum).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models.params import ParamDef

F32 = jnp.float32
NEG_INF = -1e30


# ---------------------------------------------------------------- norms ----

def rmsnorm(x, w, eps: float = 1e-5):
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * lax.rsqrt(var + eps)).astype(x.dtype) * w


def layernorm(x, w, b, eps: float = 1e-5):
    xf = x.astype(F32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * lax.rsqrt(var + eps)).astype(x.dtype) * w + b


def norm_defs(cfg: ModelConfig, dim: int | None = None):
    d = dim or cfg.d_model
    if cfg.norm_type == "layernorm":
        return {"w": ParamDef((d,), (None,), init="ones"),
                "b": ParamDef((d,), (None,), init="zeros")}
    return {"w": ParamDef((d,), (None,), init="ones")}


def apply_norm(cfg: ModelConfig, p, x):
    if cfg.norm_type == "layernorm":
        return layernorm(x, p["w"], p["b"])
    return rmsnorm(x, p["w"])


# ----------------------------------------------------------------- rope ----

def rope_cos_sin(positions, head_dim: int, theta: float):
    """positions: (...,) int -> cos/sin (..., head_dim/2) in f32."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=F32) / head_dim))
    ang = positions.astype(F32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, hd); cos/sin: (B, S, hd/2) or (S, hd/2).

    Interleaved-pair formulation (default): rotation pairs are (2i, 2i+1),
    adjacent in memory, so when the head_dim is sharded (the tp2 fallback
    for non-divisible head counts) every pair stays shard-local and RoPE
    inserts no resharding collectives — vs the split-half formulation whose
    partner lanes live hd/2 dims away (= on another shard). Equivalent model
    class (fixed basis permutation of q/k applied consistently).
    Default is split-half (classic); REPRO_ROPE=interleaved opts into the
    shard-local pairing (EXPERIMENTS §Perf It-1: measured ~4% collective
    win, superseded by the attention-DP fallback, and its activation shift
    can flip near-tie MoE routing between decode paths)."""
    import os
    xf = x.astype(F32)
    if cos.ndim == 2:  # (S, hd/2) -> broadcast batch
        cos, sin = cos[None], sin[None]
    cos, sin = cos[..., None, :], sin[..., None, :]   # (B, S, 1, hd/2)
    if os.environ.get("REPRO_ROPE") != "interleaved":
        x1, x2 = jnp.split(xf, 2, axis=-1)
        return jnp.concatenate([x1 * cos - x2 * sin,
                                x1 * sin + x2 * cos],
                               axis=-1).astype(x.dtype)
    xp = xf.reshape(x.shape[:-1] + (x.shape[-1] // 2, 2))
    x1, x2 = xp[..., 0], xp[..., 1]
    out = jnp.stack([x1 * cos - x2 * sin,
                     x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


# ----------------------------------------------- blockwise attention ------

def blockwise_attention(q, k, v, *, causal: bool, window: int | None = None,
                        q_positions=None, kv_positions=None,
                        block_kv: int = 512, scale: float | None = None):
    """Online-softmax attention; never materializes (Sq, Skv) scores.

    q: (B, Sq, H, dk);  k: (B, Skv, KV, dk);  v: (B, Skv, KV, dv)
    GQA handled by grouping q heads over KV heads. Positions default to
    arange; pass explicit positions for offset decode/prefill windows.
    Returns (B, Sq, H, dv).
    """
    B, Sq, H, dk = q.shape
    _, Skv, KV, dv = v.shape[0], v.shape[1], v.shape[2], v.shape[3]
    assert H % KV == 0
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(dk)
    if q_positions is None:
        q_positions = jnp.arange(Sq)
    if kv_positions is None:
        kv_positions = jnp.arange(Skv)

    # pad KV length to a block multiple
    nblk = (Skv + block_kv - 1) // block_kv
    pad = nblk * block_kv - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pad), constant_values=-1)
    valid = (kv_positions >= 0) if pad else None

    qg = q.reshape(B, Sq, KV, G, dk)
    kb = k.reshape(B, nblk, block_kv, KV, dk).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nblk, block_kv, KV, dv).transpose(1, 0, 2, 3, 4)
    pb = kv_positions.reshape(nblk, block_kv)
    vmask = (valid.reshape(nblk, block_kv) if valid is not None
             else jnp.ones((nblk, block_kv), bool))

    def step(carry, blk):
        m, l, acc = carry
        kj, vj, pj, okj = blk
        s = jnp.einsum("bqkgd,bjkd->bqkgj", qg, kj,
                       preferred_element_type=F32) * scale
        mask = okj[None, None, None, None, :]
        if causal:
            cm = pj[None, :] <= q_positions[:, None]        # (Sq, bk)
            mask = mask & cm[None, :, None, None, :]
        if window is not None:
            wm = pj[None, :] > (q_positions[:, None] - window)
            mask = mask & wm[None, :, None, None, :]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqkgj,bjkd->bqkgd", p.astype(vj.dtype), vj,
                        preferred_element_type=F32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, KV, G), NEG_INF, F32)
    l0 = jnp.zeros((B, Sq, KV, G), F32)
    a0 = jnp.zeros((B, Sq, KV, G, dv), F32)
    # remat each KV step: backward recomputes scores (flash-style), so the
    # live set stays O(Sq x block_kv) instead of O(Sq x Skv).
    (m, l, acc), _ = lax.scan(jax.checkpoint(step), (m0, l0, a0),
                              (kb, vb, pb, vmask))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Sq, H, dv).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, k_new, v_new, *, kv_len: int,
                     window: int | None = None, scale: float | None = None,
                     cache_positions=None):
    """Single-token attention against a (possibly seq-sharded) cache.

    q: (B, 1, H, dk); caches: (B, S, KV, d*); k_new/v_new: (B, 1, KV, d*).
    The new token's KV is attended separately (no in-graph cache mutation
    needed for the dry-run path; the serving loop owns cache writes).
    Softmax max/sum over the sharded S axis lower to all-reduces under SPMD.
    ``cache_positions``: absolute token position of each cache slot (for
    rolled sliding-window caches); defaults to arange(S).
    """
    B, _, H, dk = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    dv = v_cache.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(dk)
    qg = q.reshape(B, KV, G, dk)

    s_c = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                     preferred_element_type=F32) * scale
    S = k_cache.shape[1]
    pos = jnp.arange(S) if cache_positions is None else cache_positions
    mask = (pos < kv_len) & (pos >= 0)
    if window is not None:
        mask = mask & (pos > kv_len - window)
    s_c = jnp.where(mask[None, None, None, :], s_c, NEG_INF)
    s_n = jnp.einsum("bkgd,bjkd->bkgj", qg, k_new,
                     preferred_element_type=F32) * scale   # (B,KV,G,1)

    m = jnp.maximum(jnp.max(s_c, axis=-1), s_n[..., 0])
    p_c = jnp.exp(s_c - m[..., None])
    p_n = jnp.exp(s_n - m[..., None])
    l = jnp.sum(p_c, axis=-1) + p_n[..., 0]
    ctx = jnp.einsum("bkgs,bskd->bkgd", p_c.astype(v_cache.dtype), v_cache,
                     preferred_element_type=F32)
    ctx = ctx + p_n * v_new.reshape(B, KV, 1, dv).astype(F32)
    out = ctx / l[..., None]
    return out.reshape(B, 1, H, dv).astype(q.dtype)


# --------------------------------------------------------- GQA attention ---

def attn_defs(cfg: ModelConfig):
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    d = {
        "wq": ParamDef((D, H, hd), ("fsdp", "tp", "tp2"), init="scaled", fan_in=D),
        "wk": ParamDef((D, KV, hd), ("fsdp", "tp", "tp2"), init="scaled", fan_in=D),
        "wv": ParamDef((D, KV, hd), ("fsdp", "tp", "tp2"), init="scaled", fan_in=D),
        "wo": ParamDef((H, hd, D), ("tp", "tp2", "fsdp"), init="scaled", fan_in=H * hd),
    }
    if cfg.qkv_bias:
        d["bq"] = ParamDef((H, hd), ("tp", None), init="zeros")
        d["bk"] = ParamDef((KV, hd), ("tp", None), init="zeros")
        d["bv"] = ParamDef((KV, hd), ("tp", None), init="zeros")
    return d


def cross_attn_defs(cfg: ModelConfig):
    return attn_defs(cfg)


def _qkv(cfg, p, x, x_kv=None):
    x_kv = x if x_kv is None else x_kv
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"], preferred_element_type=F32)
    k = jnp.einsum("bsd,dhk->bshk", x_kv, p["wk"], preferred_element_type=F32)
    v = jnp.einsum("bsd,dhk->bshk", x_kv, p["wv"], preferred_element_type=F32)
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return q.astype(x.dtype), k.astype(x.dtype), v.astype(x.dtype)


def self_attention(cfg: ModelConfig, p, x, positions, *, window=None,
                   block_kv: int = 512, shardings=None):
    """Full-sequence causal self-attention (train / prefill).
    Returns (out, (k, v)) — caller decides whether to keep the cache.
    An "attn_qkv" sharding (batch over every mesh axis) switches the region
    to pure DP when head counts don't divide the tensor axis
    (REPRO_ATTN_DP=0 disables, for perf A/B)."""
    import os
    spec = None
    if shardings and os.environ.get("REPRO_ATTN_DP") != "0":
        spec = shardings.get("attn_qkv")
    q, k, v = _qkv(cfg, p, x)
    if spec is not None:
        q = lax.with_sharding_constraint(q, spec)
        k = lax.with_sharding_constraint(k, spec)
        v = lax.with_sharding_constraint(v, spec)
    cos, sin = rope_cos_sin(positions, cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    kvpos = positions if positions.ndim == 1 else positions[0]
    qpos = kvpos
    out = blockwise_attention(q, k, v, causal=True, window=window,
                              q_positions=qpos, kv_positions=kvpos,
                              block_kv=block_kv)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"], preferred_element_type=F32)
    return out.astype(x.dtype), (k, v)


def self_attention_decode(cfg: ModelConfig, p, x, pos, cache, *, window=None):
    """x: (B, 1, D); pos: scalar int (current position); cache: {'k','v'}.
    A windowed cache holds the last S tokens in time order (rolled), so its
    slot i corresponds to absolute position pos - S + i.
    Returns (out, (k_new, v_new)) — new KV for position `pos`."""
    q, k_new, v_new = _qkv(cfg, p, x)
    posv = jnp.asarray([pos]) if jnp.ndim(pos) == 0 else pos[None]
    cos, sin = rope_cos_sin(posv.reshape(1), cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k_new = apply_rope(k_new, cos, sin)
    S = cache["k"].shape[1]
    cache_positions = None
    if window is not None and S <= window:
        cache_positions = pos - S + jnp.arange(S)
    out = decode_attention(q, cache["k"], cache["v"], k_new, v_new,
                           kv_len=pos, window=window,
                           cache_positions=cache_positions)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"], preferred_element_type=F32)
    return out.astype(x.dtype), (k_new, v_new)


def cross_attention(cfg: ModelConfig, p, x, kv_cache):
    """Cross-attention to precomputed encoder/image K,V: (B, Senc, KV, hd)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"],
                   preferred_element_type=F32).astype(x.dtype)
    out = blockwise_attention(q, kv_cache["k"], kv_cache["v"], causal=False)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"], preferred_element_type=F32)
    return out.astype(x.dtype)


def cross_kv(cfg: ModelConfig, p, x_enc):
    k = jnp.einsum("bsd,dhk->bshk", x_enc, p["wk"], preferred_element_type=F32)
    v = jnp.einsum("bsd,dhk->bshk", x_enc, p["wv"], preferred_element_type=F32)
    return {"k": k.astype(x_enc.dtype), "v": v.astype(x_enc.dtype)}


# ------------------------------------------------------------------ MLA ----

def mla_defs(cfg: ModelConfig):
    D, H = cfg.d_model, cfg.num_heads
    nope, rope_d, dv, R = cfg.head_dim, cfg.rope_head_dim, cfg.v_hd, cfg.kv_lora_rank
    return {
        "wq": ParamDef((D, H, nope + rope_d), ("fsdp", "tp", None), init="scaled", fan_in=D),
        "w_dkv": ParamDef((D, R), ("fsdp", None), init="scaled", fan_in=D),
        "w_kr": ParamDef((D, rope_d), ("fsdp", None), init="scaled", fan_in=D),
        "w_uk": ParamDef((H, R, nope), ("tp", None, None), init="scaled", fan_in=R),
        "w_uv": ParamDef((H, R, dv), ("tp", None, None), init="scaled", fan_in=R),
        "wo": ParamDef((H, dv, D), ("tp", None, "fsdp"), init="scaled", fan_in=H * dv),
        "kv_norm": ParamDef((R,), (None,), init="ones"),
    }


def _mla_q(cfg, p, x, positions):
    nope, rope_d = cfg.head_dim, cfg.rope_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"],
                   preferred_element_type=F32).astype(x.dtype)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    cos, sin = rope_cos_sin(positions, rope_d, cfg.rope_theta)
    return q_nope, apply_rope(q_rope, cos, sin)


def _mla_ckv(cfg, p, x, positions):
    ckv = rmsnorm(jnp.einsum("bsd,dr->bsr", x, p["w_dkv"],
                             preferred_element_type=F32).astype(x.dtype),
                  p["kv_norm"])
    kr = jnp.einsum("bsd,dk->bsk", x, p["w_kr"],
                    preferred_element_type=F32).astype(x.dtype)
    cos, sin = rope_cos_sin(positions, cfg.rope_head_dim, cfg.rope_theta)
    kr = apply_rope(kr[:, :, None, :], cos, sin)[:, :, 0, :]
    return ckv, kr


def mla_attention(cfg: ModelConfig, p, x, positions, *, block_kv=512):
    """Train/prefill MLA: expand per-head K/V from latents, combined-head
    blockwise attention. Returns (out, (ckv, k_rope)) cache."""
    nope, rope_d, dv = cfg.head_dim, cfg.rope_head_dim, cfg.v_hd
    q_nope, q_rope = _mla_q(cfg, p, x, positions)
    ckv, kr = _mla_ckv(cfg, p, x, positions)
    k_nope = jnp.einsum("bsr,hrk->bshk", ckv, p["w_uk"],
                        preferred_element_type=F32).astype(x.dtype)
    v = jnp.einsum("bsr,hrk->bshk", ckv, p["w_uv"],
                   preferred_element_type=F32).astype(x.dtype)
    H = cfg.num_heads
    k_rope_b = jnp.broadcast_to(kr[:, :, None, :], kr.shape[:2] + (H, rope_d))
    qc = jnp.concatenate([q_nope, q_rope], -1)
    kc = jnp.concatenate([k_nope, k_rope_b], -1)
    kvpos = positions if positions.ndim == 1 else positions[0]
    out = blockwise_attention(qc, kc, v, causal=True, q_positions=kvpos,
                              kv_positions=kvpos, block_kv=block_kv,
                              scale=1.0 / math.sqrt(nope + rope_d))
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"], preferred_element_type=F32)
    return out.astype(x.dtype), (ckv, kr)


def mla_attention_decode(cfg: ModelConfig, p, x, pos, cache):
    """Absorbed-form MLA decode: score/value directly in the latent space.
    cache: {'ckv': (B, S, R), 'kr': (B, S, rope_d)} (S may be sharded)."""
    nope, rope_d, dv = cfg.head_dim, cfg.rope_head_dim, cfg.v_hd
    B = x.shape[0]
    posv = jnp.asarray([pos]).reshape(1)
    q_nope, q_rope = _mla_q(cfg, p, x, posv)
    ckv_new, kr_new = _mla_ckv(cfg, p, x, posv)
    # absorb W_uk into q: (B,1,H,nope) @ (H,R,nope) -> (B,H,R)
    q_lat = jnp.einsum("bshk,hrk->bhr", q_nope, p["w_uk"],
                       preferred_element_type=F32)
    scale = 1.0 / math.sqrt(nope + rope_d)
    s_c = (jnp.einsum("bhr,bsr->bhs", q_lat, cache["ckv"].astype(F32))
           + jnp.einsum("bshk,bSk->bhS", q_rope.astype(F32),
                        cache["kr"].astype(F32))) * scale
    S = cache["ckv"].shape[1]
    mask = jnp.arange(S) < pos
    s_c = jnp.where(mask[None, None, :], s_c, NEG_INF)
    s_n = (jnp.einsum("bhr,bsr->bh", q_lat, ckv_new.astype(F32))
           + jnp.einsum("bshk,bsk->bh", q_rope.astype(F32),
                        kr_new.astype(F32))) * scale
    m = jnp.maximum(jnp.max(s_c, -1), s_n)
    p_c = jnp.exp(s_c - m[..., None])
    p_n = jnp.exp(s_n - m)
    l = p_c.sum(-1) + p_n
    ctx = jnp.einsum("bhs,bsr->bhr", p_c, cache["ckv"].astype(F32))
    ctx = (ctx + p_n[..., None] * ckv_new[:, 0, None, :].astype(F32)) / l[..., None]
    v = jnp.einsum("bhr,hrk->bhk", ctx, p["w_uv"].astype(F32))
    out = jnp.einsum("bhk,hkd->bd", v, p["wo"].astype(F32))
    return out[:, None, :].astype(x.dtype), (ckv_new, kr_new)


# ------------------------------------------------------------------ MLP ----

def mlp_defs(cfg: ModelConfig, d_ff: int | None = None):
    D, F = cfg.d_model, d_ff or cfg.d_ff
    if cfg.act == "swiglu":
        return {"wg": ParamDef((D, F), ("fsdp", "tp"), init="scaled", fan_in=D),
                "wu": ParamDef((D, F), ("fsdp", "tp"), init="scaled", fan_in=D),
                "wd": ParamDef((F, D), ("tp", "fsdp"), init="scaled", fan_in=F)}
    return {"w1": ParamDef((D, F), ("fsdp", "tp"), init="scaled", fan_in=D),
            "b1": ParamDef((F,), ("tp",), init="zeros"),
            "w2": ParamDef((F, D), ("tp", "fsdp"), init="scaled", fan_in=F),
            "b2": ParamDef((D,), (None,), init="zeros")}


def mlp(cfg: ModelConfig, p, x):
    if cfg.act == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["wg"], preferred_element_type=F32)
        u = jnp.einsum("bsd,df->bsf", x, p["wu"], preferred_element_type=F32)
        h = (jax.nn.silu(g) * u).astype(x.dtype)
        return jnp.einsum("bsf,fd->bsd", h, p["wd"],
                          preferred_element_type=F32).astype(x.dtype)
    h = jnp.einsum("bsd,df->bsf", x, p["w1"], preferred_element_type=F32) + p["b1"]
    h = jax.nn.gelu(h).astype(x.dtype)
    return (jnp.einsum("bsf,fd->bsd", h, p["w2"],
                       preferred_element_type=F32) + p["b2"]).astype(x.dtype)


# ------------------------------------------------------------------ MoE ----

def moe_defs(cfg: ModelConfig):
    D, E, F = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    d = {
        "router": ParamDef((D, E), (None, None), init="scaled", fan_in=D,
                           dtype=jnp.float32),
        "wg": ParamDef((E, D, F), ("ep", "fsdp", "tp"), init="scaled", fan_in=D),
        "wu": ParamDef((E, D, F), ("ep", "fsdp", "tp"), init="scaled", fan_in=D),
        "wd": ParamDef((E, F, D), ("ep", "tp", "fsdp"), init="scaled", fan_in=F),
    }
    if cfg.num_shared_experts:
        Fs = F * cfg.num_shared_experts
        d["shared"] = mlp_defs(cfg, d_ff=Fs)
    return d


def moe(cfg: ModelConfig, p, x, *, return_aux: bool = False,
        dispatch_spec=None):
    """Top-k MoE with sort-based capacity dispatch (drop-on-overflow).

    x: (B, S, D). Tokens are flattened, routed to top-k experts, packed into
    an (E, C, D) buffer by expert (C = capacity), processed by batched expert
    matmuls, and combined with router weights. Overflowing tokens fall back
    to zero contribution from that expert (standard capacity dropping).
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    T = B * S
    xt = x.reshape(T, D)

    logits = (xt.astype(F32) @ p["router"]).astype(F32)        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = lax.top_k(probs, K)                               # (T, K)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)

    C = int(math.ceil(T * K * cfg.capacity_factor / E))
    C = max(C, 1)

    flat_e = idx.reshape(-1)                                   # (T*K,)
    # position of each (token, k) within its expert, by stable sort
    order = jnp.argsort(flat_e, stable=True)                   # (T*K,)
    ranks = jnp.zeros((T * K,), jnp.int32).at[order].set(
        jnp.arange(T * K, dtype=jnp.int32))
    # rank within expert = rank - (# entries routed to smaller experts)
    counts = jnp.bincount(flat_e, length=E)
    offsets = jnp.concatenate([jnp.zeros(1, jnp.int32),
                               jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    pos_in_e = ranks - offsets[flat_e]
    keep = pos_in_e < C
    slot = jnp.where(keep, flat_e * C + pos_in_e, E * C)       # drop -> OOB

    buf = jnp.zeros((E * C + 1, D), xt.dtype)
    tok_ids = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    buf = buf.at[slot].set(xt[tok_ids], mode="drop")
    xe = buf[:E * C].reshape(E, C, D)
    import os
    if os.environ.get("REPRO_MOE_EP") == "0":
        dispatch_spec = None   # perf A/B switch (EXPERIMENTS §Perf)
    if dispatch_spec is not None:
        # expert-parallel constraint: without it GSPMD replicates the
        # dispatch buffer and every device computes ALL experts (measured
        # 14x useful-flops waste on deepseek-v2 before this constraint)
        xe = lax.with_sharding_constraint(xe, dispatch_spec)

    g = jnp.einsum("ecd,edf->ecf", xe, p["wg"], preferred_element_type=F32)
    u = jnp.einsum("ecd,edf->ecf", xe, p["wu"], preferred_element_type=F32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    ye = jnp.einsum("ecf,efd->ecd", h, p["wd"],
                    preferred_element_type=F32)                # (E, C, D) f32
    if dispatch_spec is not None:
        ye = lax.with_sharding_constraint(ye, dispatch_spec)

    flat_y = ye.reshape(E * C, D)
    gathered = jnp.where(keep[:, None], flat_y[jnp.minimum(slot, E * C - 1)], 0.0)
    contrib = gathered * w.reshape(-1)[:, None]
    out = jnp.zeros((T, D), F32).at[tok_ids].add(contrib)

    if cfg.num_shared_experts:
        out = out + mlp(cfg, p["shared"], x).reshape(T, D).astype(F32)

    out = out.reshape(B, S, D).astype(x.dtype)
    if return_aux:
        # load-balancing aux loss (Switch-style)
        me = probs.mean(0)
        ce = jnp.bincount(flat_e, length=E).astype(F32) / (T * K)
        aux = E * jnp.sum(me * ce)
        return out, aux
    return out
