"""Parameter definitions: one source of truth for shape, init, and sharding.

A model is described as a pytree (nested dicts) of ``ParamDef`` leaves. From
that single tree we derive: materialized parameters (``init_params``), the
matching ``PartitionSpec`` tree (``param_specs``), ``ShapeDtypeStruct`` stand-ins
for dry-runs (``param_shapes``), and parameter counts (``count_params``).

Sharding axes are *logical* names resolved against the physical mesh at spec
build time. A dimension is sharded only when divisible by the product of the
mapped mesh axes; otherwise it silently falls back to replication for that
dimension (small models on big meshes).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    # Logical axis per dim: None (replicated) or a logical name ("tp", "fsdp",
    # "ep", "stack", ...). Resolved to mesh axes via the rules dict.
    axes: tuple[str | None, ...]
    init: str = "normal"        # normal | zeros | ones | scaled
    scale: float = 1.0          # stddev multiplier (for normal/scaled)
    fan_in: int | None = None   # for "scaled": stddev = scale / sqrt(fan_in)
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def tree_map_defs(fn: Callable[[ParamDef], Any], tree: Pytree) -> Pytree:
    return jax.tree.map(fn, tree, is_leaf=is_def)


def stack_defs(tree: Pytree, n: int) -> Pytree:
    """Add a leading stacked-layer dimension to every def in the tree."""
    def add(d: ParamDef) -> ParamDef:
        return dataclasses.replace(d, shape=(n,) + d.shape, axes=("stack",) + d.axes)
    return tree_map_defs(add, tree)


def _resolve_axis(logical: str | None, dim: int, rules: dict[str, tuple[str, ...]],
                  mesh_sizes: dict[str, int]):
    """Map a logical axis to mesh axes, dropping it if not divisible."""
    if logical is None:
        return None
    mesh_axes = rules.get(logical, ())
    if not mesh_axes:
        return None
    size = math.prod(mesh_sizes[a] for a in mesh_axes)
    if size <= 1 or dim % size != 0:
        return None
    return mesh_axes if len(mesh_axes) > 1 else mesh_axes[0]


def param_specs(tree: Pytree, rules: dict[str, tuple[str, ...]],
                mesh_sizes: dict[str, int]) -> Pytree:
    def spec(d: ParamDef) -> P:
        used: set[str] = set()
        out = []
        for a, s in zip(d.axes, d.shape):
            r = _resolve_axis(a, s, rules, mesh_sizes)
            names = (r,) if isinstance(r, str) else (r or ())
            if r is None or any(n in used for n in names):
                out.append(None)  # a mesh axis may appear at most once per spec
            else:
                used.update(names)
                out.append(r)
        return P(*out)
    return tree_map_defs(spec, tree)


def param_shapes(tree: Pytree) -> Pytree:
    return tree_map_defs(lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), tree)


def count_params(tree: Pytree) -> int:
    return sum(math.prod(d.shape) for d in jax.tree.leaves(tree, is_leaf=is_def))


def init_params(tree: Pytree, key: jax.Array) -> Pytree:
    """Materialize parameters. Deterministic per-leaf keys derived by path."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_def)
    keys = jax.random.split(key, max(len(leaves), 1))

    def make(d: ParamDef, k):
        if d.init == "zeros":
            return jnp.zeros(d.shape, d.dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, d.dtype)
        if d.init == "scaled":
            fan = d.fan_in if d.fan_in is not None else (d.shape[-2] if len(d.shape) >= 2 else d.shape[-1])
            std = d.scale / math.sqrt(max(fan, 1))
        else:  # normal
            std = 0.02 * d.scale
        return (jax.random.normal(k, d.shape, jnp.float32) * std).astype(d.dtype)

    return jax.tree.unflatten(treedef, [make(d, k) for d, k in zip(leaves, keys)])
