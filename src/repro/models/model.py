"""Model assembly: composable decoder / encoder-decoder stacks over the layer
zoo, with scanned super-blocks (homogeneous HLO), remat, and functional
caches for decode.

Entrypoints
-----------
- ``model_defs(cfg)``        -> ParamDef pytree (single source of truth)
- ``forward_train(...)``     -> logits over the full sequence
- ``forward_prefill(...)``   -> (last-token logits, caches)
- ``forward_decode(...)``    -> (logits, new cache deltas) for one token
- ``loss_fn(...)``           -> scalar LM loss (+ MoE aux)
- ``cache_shapes(cfg, ...)`` -> pytree of cache array shapes for decode
- ``count_model_params(cfg)``/``active_params(cfg)`` -> roofline N
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.params import ParamDef, stack_defs, count_params, is_def

F32 = jnp.float32


def cst(x, shardings, key):
    """with_sharding_constraint if a spec for `key` was provided."""
    if shardings and key in shardings and shardings[key] is not None:
        return lax.with_sharding_constraint(x, shardings[key])
    return x


# ------------------------------------------------------------- defs tree ---

def layer_defs(cfg: ModelConfig, l: int):
    kind = cfg.layer_kind(l)
    d: dict[str, Any] = {"norm1": L.norm_defs(cfg)}
    if kind == "attn":
        d["mixer"] = L.mla_defs(cfg) if cfg.use_mla else L.attn_defs(cfg)
    elif kind == "ssm":
        d["mixer"] = S.ssm_defs(cfg)
    elif kind == "cross":
        d["mixer"] = L.cross_attn_defs(cfg)
    if cfg.is_encoder_decoder:
        d["norm_x"] = L.norm_defs(cfg)
        d["xattn"] = L.cross_attn_defs(cfg)
    if cfg.d_ff > 0 or cfg.is_moe_layer(l):
        d["norm2"] = L.norm_defs(cfg)
        d["ffn"] = L.moe_defs(cfg) if cfg.is_moe_layer(l) else L.mlp_defs(cfg)
    return d


def encoder_layer_defs(cfg: ModelConfig):
    return {"norm1": L.norm_defs(cfg), "mixer": L.attn_defs(cfg),
            "norm2": L.norm_defs(cfg), "ffn": L.mlp_defs(cfg)}


def model_defs(cfg: ModelConfig):
    Vp, D = cfg.padded_vocab(), cfg.d_model
    defs: dict[str, Any] = {
        "embed": ParamDef((Vp, D), ("tp", "fsdp"), init="normal"),
        "final_norm": L.norm_defs(cfg),
    }
    if not cfg.tie_embeddings:
        defs["unembed"] = ParamDef((D, Vp), ("fsdp", "tp"),
                                   init="scaled", fan_in=D)
    npfx = cfg.first_dense_layers
    if npfx:
        defs["prefix"] = {f"p{i}": layer_defs(cfg, i) for i in range(npfx)}
    nscan = cfg.num_layers - npfx
    assert nscan % cfg.block_period == 0
    nb = nscan // cfg.block_period
    block = {f"s{i}": layer_defs(cfg, npfx + i) for i in range(cfg.block_period)}
    defs["blocks"] = stack_defs(block, nb)
    if cfg.is_encoder_decoder:
        defs["encoder"] = {
            "blocks": stack_defs(encoder_layer_defs(cfg), cfg.encoder_layers),
            "final_norm": L.norm_defs(cfg),
        }
    return defs


def n_scan_blocks(cfg: ModelConfig) -> int:
    return (cfg.num_layers - cfg.first_dense_layers) // cfg.block_period


# --------------------------------------------------------- layer forward ---

def _ffn(cfg, lp, x, moe_layer: bool, aux, shardings=None):
    h = L.apply_norm(cfg, lp["norm2"], x)
    if moe_layer:
        spec = shardings.get("moe_dispatch") if shardings else None
        y, a = L.moe(cfg, lp["ffn"], h, return_aux=True, dispatch_spec=spec)
        return x + y, aux + a
    return x + L.mlp(cfg, lp["ffn"], h), aux


def layer_forward(cfg: ModelConfig, lp, x, l: int, *, positions, mode: str,
                  cache=None, enc_out=None, img_embeds=None, shardings=None):
    """One layer, full-sequence (train/prefill). Returns (x, new_cache, aux)."""
    kind = cfg.layer_kind(l)
    aux = jnp.zeros((), F32)
    h = L.apply_norm(cfg, lp["norm1"], x)
    new_cache = {}
    if kind == "attn":
        if cfg.use_mla:
            y, (ckv, kr) = L.mla_attention(cfg, lp["mixer"], h, positions)
            new_cache = {"ckv": ckv, "kr": kr}
        else:
            y, (k, v) = L.self_attention(cfg, lp["mixer"], h, positions,
                                         window=cfg.sliding_window,
                                         shardings=shardings)
            if mode == "prefill":
                if cfg.sliding_window:   # ring cache: keep last `window`
                    w = min(cfg.sliding_window, k.shape[1])
                    k, v = k[:, -w:], v[:, -w:]
                new_cache = {"k": cst(k, shardings, "kv_cache"),
                             "v": cst(v, shardings, "kv_cache")}
    elif kind == "ssm":
        y, (final_state, conv_tail) = S.mamba_block(cfg, lp["mixer"], h)
        if mode == "prefill":
            new_cache = {"state": final_state.astype(x.dtype),
                         "conv": conv_tail.astype(x.dtype)}
    elif kind == "cross":
        kv = L.cross_kv(cfg, lp["mixer"], img_embeds)
        y = L.cross_attention(cfg, lp["mixer"], h, kv)
        if mode == "prefill":
            new_cache = {"k": kv["k"], "v": kv["v"]}
    x = x + y
    if cfg.is_encoder_decoder:
        hx = L.apply_norm(cfg, lp["norm_x"], x)
        kv = L.cross_kv(cfg, lp["xattn"], enc_out)
        x = x + L.cross_attention(cfg, lp["xattn"], hx, kv)
        if mode == "prefill":
            new_cache["xk"], new_cache["xv"] = kv["k"], kv["v"]
    if "ffn" in lp:
        x, aux = _ffn(cfg, lp, x, cfg.is_moe_layer(l), aux, shardings)
    x = cst(x, shardings, "residual")
    return x, new_cache, aux


def layer_decode(cfg: ModelConfig, lp, x, l: int, *, pos, cache,
                 shardings=None):
    """One layer, one token. Returns (x, cache_delta)."""
    kind = cfg.layer_kind(l)
    h = L.apply_norm(cfg, lp["norm1"], x)
    delta = {}
    if kind == "attn":
        if cfg.use_mla:
            y, (ckv, kr) = L.mla_attention_decode(cfg, lp["mixer"], h, pos,
                                                  cache)
            delta = {"ckv": ckv, "kr": kr}
        else:
            y, (kn, vn) = L.self_attention_decode(
                cfg, lp["mixer"], h, pos, cache, window=cfg.sliding_window)
            delta = {"k": kn, "v": vn}
    elif kind == "ssm":
        y, new_cache = S.mamba_block_decode(cfg, lp["mixer"], h, cache)
        delta = new_cache
    elif kind == "cross":
        y = L.cross_attention(cfg, lp["mixer"], h,
                              {"k": cache["k"], "v": cache["v"]})
    x = x + y
    if cfg.is_encoder_decoder:
        hx = L.apply_norm(cfg, lp["norm_x"], x)
        x = x + L.cross_attention(cfg, lp["xattn"], hx,
                                  {"k": cache["xk"], "v": cache["xv"]})
    if "ffn" in lp:
        x, _ = _ffn(cfg, lp, x, cfg.is_moe_layer(l), jnp.zeros((), F32),
                    shardings)
    return x, delta


# ----------------------------------------------------------- full stacks ---

def _embed(cfg, params, tokens, shardings):
    x = jnp.take(params["embed"], tokens, axis=0)
    return cst(x, shardings, "residual")


def _logits(cfg, params, x, shardings=None):
    x = L.apply_norm(cfg, params["final_norm"], x)
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bsd,dv->bsv", x, w, preferred_element_type=F32)
    logits = cst(logits, shardings, "logits")
    # mask padded vocab entries
    Vp = cfg.padded_vocab()
    if Vp != cfg.vocab_size:
        mask = jnp.arange(Vp) < cfg.vocab_size
        logits = jnp.where(mask, logits, -1e9)
    return logits


def _encoder_forward(cfg, params, enc_embeds, shardings):
    ep = params["encoder"]
    pos = jnp.arange(enc_embeds.shape[1])

    def body(x, bp):
        h = L.apply_norm(cfg, bp["norm1"], x)
        q, k, v = L._qkv(cfg, bp["mixer"], h)
        cos, sin = L.rope_cos_sin(pos, cfg.head_dim, cfg.rope_theta)
        q, k = L.apply_rope(q, cos, sin), L.apply_rope(k, cos, sin)
        y = L.blockwise_attention(q, k, v, causal=False)
        y = jnp.einsum("bshk,hkd->bsd", y, bp["mixer"]["wo"],
                       preferred_element_type=F32).astype(x.dtype)
        x = x + y
        h2 = L.apply_norm(cfg, bp["norm2"], x)
        x = x + L.mlp(cfg, bp["ffn"], h2)
        return cst(x, shardings, "residual"), None

    x, _ = lax.scan(body, enc_embeds, ep["blocks"])
    return L.apply_norm(cfg, ep["final_norm"], x)


def forward_train(cfg: ModelConfig, params, tokens, *, enc_embeds=None,
                  img_embeds=None, shardings=None, remat: bool = True,
                  unroll: bool = False):
    """tokens: (B, S) -> logits (B, S, Vp). Also returns MoE aux loss."""
    positions = jnp.arange(tokens.shape[1])
    x = _embed(cfg, params, tokens, shardings)
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _encoder_forward(cfg, params, enc_embeds, shardings)

    aux_total = jnp.zeros((), F32)
    for i in range(cfg.first_dense_layers):
        x, _, a = layer_forward(cfg, params["prefix"][f"p{i}"], x, i,
                                positions=positions, mode="train",
                                enc_out=enc_out, img_embeds=img_embeds,
                                shardings=shardings)
        aux_total += a

    npfx = cfg.first_dense_layers

    def block_fn(carry, bp):
        x, aux = carry
        for i in range(cfg.block_period):
            x, _, a = layer_forward(cfg, bp[f"s{i}"], x, npfx + i,
                                    positions=positions, mode="train",
                                    enc_out=enc_out, img_embeds=img_embeds,
                                    shardings=shardings)
            aux = aux + a
        return (x, aux), None

    fn = jax.checkpoint(block_fn) if remat else block_fn
    # unroll=True removes the while loop so compiled.cost_analysis() counts
    # every layer (XLA cost analysis counts a loop body once) — used by the
    # dry-run's measurement mode.
    (x, aux_total), _ = lax.scan(fn, (x, aux_total), params["blocks"],
                                 unroll=True if unroll else 1)
    return _logits(cfg, params, x, shardings), aux_total


def forward_prefill(cfg: ModelConfig, params, tokens, *, enc_embeds=None,
                    img_embeds=None, shardings=None, unroll: bool = False):
    """tokens: (B, S) -> (logits for last position (B, Vp), caches pytree).

    Cache leaves are stacked over scan blocks: (nb, B, ...)."""
    positions = jnp.arange(tokens.shape[1])
    x = _embed(cfg, params, tokens, shardings)
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _encoder_forward(cfg, params, enc_embeds, shardings)

    prefix_caches = {}
    for i in range(cfg.first_dense_layers):
        x, c, _ = layer_forward(cfg, params["prefix"][f"p{i}"], x, i,
                                positions=positions, mode="prefill",
                                enc_out=enc_out, img_embeds=img_embeds,
                                shardings=shardings)
        prefix_caches[f"p{i}"] = c
    npfx = cfg.first_dense_layers

    def block_fn(x, bp):
        caches = {}
        for i in range(cfg.block_period):
            x, c, _ = layer_forward(cfg, bp[f"s{i}"], x, npfx + i,
                                    positions=positions, mode="prefill",
                                    enc_out=enc_out, img_embeds=img_embeds,
                                    shardings=shardings)
            caches[f"s{i}"] = c
        return x, caches

    x, block_caches = lax.scan(block_fn, x, params["blocks"],
                               unroll=True if unroll else 1)
    logits = _logits(cfg, params, x[:, -1:, :], shardings)[:, 0]
    return logits, {"prefix": prefix_caches, "blocks": block_caches}


def forward_decode(cfg: ModelConfig, params, token, pos, caches, *,
                   shardings=None, unroll: bool = False):
    """token: (B, 1) int32; pos: int (static or traced); caches from
    ``cache_shapes``. Returns (logits (B, Vp), cache deltas)."""
    x = _embed(cfg, params, token, shardings)
    npfx = cfg.first_dense_layers
    prefix_deltas = {}
    for i in range(npfx):
        x, d = layer_decode(cfg, params["prefix"][f"p{i}"], x, i, pos=pos,
                            cache=caches["prefix"][f"p{i}"],
                            shardings=shardings)
        prefix_deltas[f"p{i}"] = d

    def block_fn(x, inp):
        bp, bc = inp
        deltas = {}
        for i in range(cfg.block_period):
            x, d = layer_decode(cfg, bp[f"s{i}"], x, npfx + i, pos=pos,
                                cache=bc[f"s{i}"], shardings=shardings)
            deltas[f"s{i}"] = d
        return x, deltas

    x, block_deltas = lax.scan(block_fn, x, (params["blocks"],
                                             caches["blocks"]),
                               unroll=True if unroll else 1)
    logits = _logits(cfg, params, x, shardings)[:, 0]
    return logits, {"prefix": prefix_deltas, "blocks": block_deltas}


# ----------------------------------------------------------------- loss ----

def loss_fn(cfg: ModelConfig, params, batch, *, shardings=None,
            remat: bool = True, aux_weight: float = 0.01,
            z_weight: float = 1e-4, unroll: bool = False):
    logits, aux = forward_train(
        cfg, params, batch["tokens"],
        enc_embeds=batch.get("enc_embeds"), img_embeds=batch.get("img_embeds"),
        shardings=shardings, remat=remat, unroll=unroll)
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    mask = (labels >= 0).astype(F32)
    labels = jnp.maximum(labels, 0)
    # one-hot masked sum instead of gather: partitions cleanly over a
    # vocab-sharded logits tensor (partial sums -> all-reduce)
    Vp = logits.shape[-1]
    onehot = (jnp.arange(Vp)[None, None, :] == labels[..., None])
    ll = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = jnp.sum((lse - ll) * mask) / jnp.maximum(mask.sum(), 1.0)
    zloss = jnp.sum((lse ** 2) * mask) / jnp.maximum(mask.sum(), 1.0)
    loss = nll + z_weight * zloss + aux_weight * aux
    return loss, {"nll": nll, "aux": aux, "zloss": zloss}


# ----------------------------------------------------------- cache decls ---

def _layer_cache_shape(cfg: ModelConfig, l: int, batch: int, seq: int):
    kind = cfg.layer_kind(l)
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    c: dict[str, tuple] = {}
    if kind == "attn":
        if cfg.use_mla:
            c = {"ckv": (batch, seq, cfg.kv_lora_rank),
                 "kr": (batch, seq, cfg.rope_head_dim)}
        else:
            s = min(seq, cfg.sliding_window) if cfg.sliding_window else seq
            c = {"k": (batch, s, KV, hd), "v": (batch, s, KV, hd)}
    elif kind == "ssm":
        c = S.ssm_cache_shape(cfg, batch)
    elif kind == "cross":
        c = {"k": (batch, cfg.num_image_tokens, KV, hd),
             "v": (batch, cfg.num_image_tokens, KV, hd)}
    if cfg.is_encoder_decoder:
        c["xk"] = (batch, cfg.encoder_seq, KV, hd)
        c["xv"] = (batch, cfg.encoder_seq, KV, hd)
    return c


def cache_shapes(cfg: ModelConfig, batch: int, seq: int):
    """Pytree of shapes matching forward_decode's `caches` argument."""
    nb = n_scan_blocks(cfg)
    out: dict[str, Any] = {"prefix": {}, "blocks": {}}
    for i in range(cfg.first_dense_layers):
        out["prefix"][f"p{i}"] = _layer_cache_shape(cfg, i, batch, seq)
    for i in range(cfg.block_period):
        l = cfg.first_dense_layers + i
        per = _layer_cache_shape(cfg, l, batch, seq)
        out["blocks"][f"s{i}"] = {k: (nb,) + v for k, v in per.items()}
    return out


# -------------------------------------------------------------- counting ---

def count_model_params(cfg: ModelConfig) -> int:
    return count_params(model_defs(cfg))


def active_params(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE: only top-k experts active)."""
    total = count_model_params(cfg)
    if not cfg.num_experts:
        return total
    E, K = cfg.num_experts, cfg.experts_per_token
    per_expert = 3 * cfg.d_model * cfg.moe_d_ff
    n_moe_layers = sum(cfg.is_moe_layer(l) for l in range(cfg.num_layers))
    inactive = n_moe_layers * per_expert * (E - K)
    return total - inactive
