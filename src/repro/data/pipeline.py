"""Data pipeline: deterministic-by-step sharded batches with background
prefetch.

Determinism is the straggler/fault story (DESIGN.md §3): batch(step, host) is
a pure function of (seed, step, host), so any host can recompute any shard
after a restart without coordination, and restarts resume mid-epoch exactly.

Two sources:
- SyntheticLM: endless token stream from a seeded generator (a fixed
  synthetic "language" with Zipfian unigrams + local structure, so models
  actually learn and loss curves are meaningful).
- MemmapCorpus: flat uint16/uint32 token file, random crops per step.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from pathlib import Path

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


class SyntheticLM:
    """Zipf unigrams + order-2 structure: token ~ f(prev, latent topic)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V = cfg.vocab_size
        ranks = np.arange(1, V + 1, dtype=np.float64)
        self.unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
        # deterministic "grammar": each token has a preferred successor band
        self.shift = rng.integers(1, max(V // 4, 2), size=V)

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4096 + cfg.host_id)
        B, S, V = cfg.host_batch, cfg.seq_len, cfg.vocab_size
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.choice(V, size=B, p=self.unigram)
        mix = rng.random((B, S))
        noise = rng.choice(V, size=(B, S), p=self.unigram)
        for t in range(S):
            succ = (toks[:, t] + self.shift[toks[:, t]]) % V
            toks[:, t + 1] = np.where(mix[:, t] < 0.65, succ, noise[:, t])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}


class MemmapCorpus:
    def __init__(self, cfg: DataConfig, path: str | Path,
                 dtype=np.uint16):
        self.cfg = cfg
        self.data = np.memmap(path, dtype=dtype, mode="r")
        assert len(self.data) > cfg.seq_len + 1

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4096 + cfg.host_id)
        B, S = cfg.host_batch, cfg.seq_len
        starts = rng.integers(0, len(self.data) - S - 1, size=B)
        toks = np.stack([self.data[s:s + S + 1] for s in starts]).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}


class Prefetcher:
    """Background-thread prefetch of future steps (bounded queue)."""

    def __init__(self, source, start_step: int, depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        s = self._step
        while not self._stop.is_set():
            b = self.source.batch(s)
            while not self._stop.is_set():
                try:
                    self.q.put((s, b), timeout=0.2)
                    break
                except queue.Full:
                    continue
            s += 1

    def next(self):
        step, batch = self.q.get()
        return step, batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
