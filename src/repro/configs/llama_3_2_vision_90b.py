"""llama-3.2-vision-90b [vlm] — 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256; every 5th layer cross-attends to image patch embeddings.
[hf:meta-llama/Llama-3.2-90B-Vision]

The vision tower is a STUB: input_specs() supplies 6400 precomputed patch
embeddings per sample (d_model-sized), per the assignment.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    num_layers=100, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=128256, rope_theta=500_000.0,
    cross_attn_period=5, cross_attn_offset=4, num_image_tokens=6400,
    block_period=5,
))
