"""Architecture + shape configuration dataclasses and the registry."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention
    qkv_bias: bool = False
    rope_theta: float = 500_000.0
    sliding_window: Optional[int] = None     # SWA window (tokens) or None
    # MLA (deepseek)
    use_mla: bool = False
    kv_lora_rank: int = 0
    rope_head_dim: int = 64
    v_head_dim: int = 0                      # defaults to head_dim

    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    moe_layer_period: int = 1                # layer l is MoE iff l % period == offset
    moe_layer_offset: int = 0
    first_dense_layers: int = 0              # first k layers always dense
    capacity_factor: float = 1.25

    # SSM (mamba2)
    ssm_state_dim: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv_width: int = 4

    # hybrid (jamba): layer l is attention iff l % attn_layer_period == attn_layer_offset
    attn_layer_period: int = 0
    attn_layer_offset: int = 0

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500

    # vlm: layer l cross-attends to image tokens iff l % cross_attn_period == cross_attn_offset
    cross_attn_period: int = 0
    cross_attn_offset: int = 0
    num_image_tokens: int = 0

    norm_type: str = "rmsnorm"               # rmsnorm | layernorm
    act: str = "swiglu"                      # swiglu | gelu
    tie_embeddings: bool = False
    block_period: int = 1                    # layers scanned in super-blocks of this size

    def __post_init__(self):
        if self.use_mla:
            assert self.kv_lora_rank > 0
        if self.num_experts:
            assert self.experts_per_token > 0 and self.moe_d_ff > 0
        assert self.num_layers % self.block_period == 0, (self.name, "block period")

    @property
    def v_hd(self) -> int:
        return self.v_head_dim or self.head_dim

    @property
    def num_blocks(self) -> int:
        return self.num_layers // self.block_period

    @property
    def d_inner(self) -> int:                # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_num_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def layer_kind(self, l: int) -> str:
        """Mixer kind for layer index l: 'attn' | 'ssm' | 'cross'."""
        if self.family == "ssm":
            return "ssm"
        if self.attn_layer_period:
            return "attn" if l % self.attn_layer_period == self.attn_layer_offset else "ssm"
        if self.cross_attn_period and l % self.cross_attn_period == self.cross_attn_offset:
            return "cross"
        return "attn"

    def is_moe_layer(self, l: int) -> bool:
        if not self.num_experts or l < self.first_dense_layers:
            return False
        return l % self.moe_layer_period == self.moe_layer_offset

    def padded_vocab(self, multiple: int = 2048) -> int:
        return ((self.vocab_size + multiple - 1) // multiple) * multiple


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                                # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    from repro import configs as _pkg  # ensure arch modules imported
    _pkg.load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_configs() -> dict[str, ModelConfig]:
    from repro import configs as _pkg
    _pkg.load_all()
    return dict(_REGISTRY)


# Shapes skipped per arch (documented in DESIGN.md §Arch-applicability):
# long_500k requires sub-quadratic attention; run only for ssm/hybrid/SWA.
SKIPPED_CELLS: dict[tuple[str, str], str] = {
    ("whisper-small", "long_500k"): "full attention enc-dec; no sub-quadratic path",
    ("stablelm-12b", "long_500k"): "pure full attention",
    ("llama3.2-3b", "long_500k"): "pure full attention",
    ("llama3-405b", "long_500k"): "pure full attention",
    ("qwen2-7b", "long_500k"): "pure full attention",
    ("deepseek-v2-lite-16b", "long_500k"): "MLA is full attention over latents",
    ("llama-3.2-vision-90b", "long_500k"): "pure full attention",
}


def cell_is_skipped(arch: str, shape: str) -> str | None:
    return SKIPPED_CELLS.get((arch, shape))
