"""deepseek-v2-lite-16b [moe] — 27L d_model=2048, MLA (kv_lora=512, rope
head 64, 16 heads x 128), MoE 64 routed top-6 + 2 shared, moe_d_ff=1408,
first layer dense (d_ff=10944), vocab=102400. [arXiv:2405.04434]

Note: the assignment line mentions "160 routed" which is full-size V2; the
lite config implemented here is 64 routed + 2 shared, top-6, per the paper.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    num_layers=27, d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
    d_ff=10944, vocab_size=102400, rope_theta=10_000.0,
    use_mla=True, kv_lora_rank=512, rope_head_dim=64, v_head_dim=128,
    num_experts=64, num_shared_experts=2, experts_per_token=6, moe_d_ff=1408,
    first_dense_layers=1,
))
