"""Config registry. One module per assigned architecture."""
import importlib

from repro.configs.base import (  # noqa: F401
    ModelConfig, ShapeConfig, SHAPES, get_config, all_configs, register,
    cell_is_skipped, SKIPPED_CELLS,
)

_ARCH_MODULES = [
    "mamba2_130m",
    "whisper_small",
    "stablelm_12b",
    "llama3_2_3b",
    "llama3_405b",
    "qwen2_7b",
    "mixtral_8x7b",
    "deepseek_v2_lite_16b",
    "jamba_1_5_large_398b",
    "llama_3_2_vision_90b",
]

_loaded = False


def load_all() -> None:
    global _loaded
    if _loaded:
        return
    _loaded = True
    for m in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{m}")


ARCH_NAMES = [
    "mamba2-130m", "whisper-small", "stablelm-12b", "llama3.2-3b",
    "llama3-405b", "qwen2-7b", "mixtral-8x7b", "deepseek-v2-lite-16b",
    "jamba-1.5-large-398b", "llama-3.2-vision-90b",
]
