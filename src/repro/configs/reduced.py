"""Reduced (smoke-test) variants of each architecture: same family/topology,
tiny dims. Used by CPU tests and examples; the FULL configs are only ever
lowered via the dry-run (ShapeDtypeStruct, no allocation)."""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig, get_config


def reduced_config(name: str, *, layers_scale: int | None = None) -> ModelConfig:
    cfg = get_config(name)
    period = cfg.block_period
    # keep >= 2 super-blocks so the scan path is exercised
    n_layers = max(2 * period, cfg.first_dense_layers + period)
    if cfg.first_dense_layers:
        n_layers = cfg.first_dense_layers + 2 * period
    kw = dict(
        name=cfg.name + "-smoke",
        num_layers=n_layers,
        d_model=64,
        num_heads=4 if cfg.num_heads else 0,
        num_kv_heads=2 if cfg.num_kv_heads else 0,
        head_dim=16 if cfg.head_dim else 0,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        encoder_layers=2 if cfg.encoder_layers else 0,
        encoder_seq=24 if cfg.is_encoder_decoder else cfg.encoder_seq,
        num_image_tokens=16 if cfg.num_image_tokens else 0,
    )
    if cfg.use_mla:
        kw.update(kv_lora_rank=32, rope_head_dim=8, head_dim=16, v_head_dim=16,
                  num_kv_heads=4)
    if cfg.num_experts:
        # capacity_factor = E/K makes routing dropless, so prefill+decode is
        # bitwise-consistent with the full forward regardless of token count.
        kw.update(num_experts=4, experts_per_token=2, moe_d_ff=64,
                  capacity_factor=2.0)
    if cfg.ssm_state_dim:
        kw.update(ssm_state_dim=16, ssm_head_dim=8, ssm_chunk=8)
    if cfg.sliding_window:
        kw.update(sliding_window=16)
    return dataclasses.replace(cfg, **kw)
