"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2, Mamba:attn 7:1 interleave.
[arXiv:2403.19887]

Adaptation note (DESIGN.md): Jamba-1.5 uses Mamba-1 layers; we implement its
SSM layers with the Mamba2/SSD block (the TPU-native chunked formulation this
framework provides); state=128, head_dim=64. MoE every other layer.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=65536, rope_theta=1_000_000.0,
    num_experts=16, experts_per_token=2, moe_d_ff=24576,
    moe_layer_period=2, moe_layer_offset=1,
    attn_layer_period=8, attn_layer_offset=4,
    ssm_state_dim=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    block_period=8,
))
