"""mamba2-130m [ssm] — SSD (state-space duality), attention-free.

24L d_model=768 d_ff=0 vocab=50280 ssm_state=128. [arXiv:2405.21060]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-130m", family="ssm",
    num_layers=24, d_model=768, num_heads=0, num_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=50280,
    ssm_state_dim=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    tie_embeddings=True, norm_type="rmsnorm",
))
