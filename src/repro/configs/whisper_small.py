"""whisper-small [audio] — enc-dec transformer backbone; conv frontend is a
stub (input_specs supplies precomputed frame embeddings).

12L d_model=768 12H (kv=12) d_ff=3072 vocab=51865. [arXiv:2212.04356]
Adaptation note: rotary positions instead of Whisper's absolute embeddings
(framework-uniform position handling).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-small", family="audio",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size=51865,
    is_encoder_decoder=True, encoder_layers=12, encoder_seq=1500,
    norm_type="layernorm", act="gelu", rope_theta=10_000.0,
))
