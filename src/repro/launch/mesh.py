"""Production mesh construction.

A function, not a module-level constant: importing this module never touches
jax device state. The dry-run sets XLA_FLAGS before any jax import to get 512
placeholder host devices; everything else sees the real device count.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, model: int | None = None):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = jax.device_count()
    m = model or 1
    assert n % m == 0
    return jax.make_mesh((n // m, m), ("data", "model"))
