"""ShapeDtypeStruct stand-ins for every model input: weak-type-correct,
shardable, no device allocation. Used by the dry-run and the roofline."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distrib import sharding as SH
from repro.models import model as M
from repro.models.params import param_shapes, tree_map_defs
from repro.training.optimizer import OptConfig


def _with_sharding(shapes, specs, mesh):
    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                          sharding=NamedSharding(mesh, p)),
        shapes, specs)


def param_structs(cfg: ModelConfig, mesh: Mesh):
    defs = M.model_defs(cfg)
    shapes = param_shapes(defs)
    specs = SH.model_param_specs(cfg, mesh)
    return _with_sharding(shapes, specs, mesh)


def opt_state_structs(cfg: ModelConfig, mesh: Mesh,
                      oc: OptConfig | None = None):
    oc = oc or OptConfig()
    p = param_structs(cfg, mesh)
    mv = jax.tree.map(lambda s: jax.ShapeDtypeStruct(
        s.shape, oc.state_dtype, sharding=s.sharding), p)
    step = jax.ShapeDtypeStruct((), jnp.int32,
                                sharding=NamedSharding(mesh, P()))
    return {"m": mv, "v": jax.tree.map(lambda x: x, mv), "step": step}


def batch_structs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    sizes = SH.mesh_sizes(mesh)
    bax = SH.batch_axes(sizes, shape.global_batch)
    bspec = bax if bax else None
    B, S = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32,
                               sharding=NamedSharding(mesh, P(bspec, None)))
    out = {"tokens": tok}
    if shape.kind == "train":
        out["labels"] = jax.ShapeDtypeStruct(
            (B, S), jnp.int32, sharding=NamedSharding(mesh, P(bspec, None)))
    if cfg.is_encoder_decoder:
        out["enc_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16,
            sharding=NamedSharding(mesh, P(bspec, None, None)))
    if cfg.num_image_tokens:
        out["img_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16,
            sharding=NamedSharding(mesh, P(bspec, None, None)))
    return out


def cache_structs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    shapes = M.cache_shapes(cfg, shape.global_batch, shape.seq_len)
    specs = SH.cache_specs(cfg, mesh, shape)

    def build(shp_tree, spec_tree):
        if isinstance(shp_tree, dict):
            return {k: build(shp_tree[k], spec_tree[k]) for k in shp_tree}
        return jax.ShapeDtypeStruct(shp_tree, jnp.bfloat16,
                                    sharding=NamedSharding(mesh, spec_tree))

    return build(shapes, specs)


def input_specs(cfg_or_name, shape: ShapeConfig | str | None = None,
                mesh: Mesh | None = None):
    """All dry-run inputs for one (arch, shape) cell."""
    from repro.configs import get_config, SHAPES
    cfg = (get_config(cfg_or_name) if isinstance(cfg_or_name, str)
           else cfg_or_name)
    shape = SHAPES[shape] if isinstance(shape, str) else shape
    if mesh is None:
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh()
    sizes = SH.mesh_sizes(mesh)
    bax = SH.batch_axes(sizes, shape.global_batch)
    bspec = bax if bax else None

    out = {"params": param_structs(cfg, mesh)}
    if shape.kind == "train":
        out["opt_state"] = opt_state_structs(cfg, mesh)
        out["batch"] = batch_structs(cfg, shape, mesh)
    elif shape.kind == "prefill":
        out["batch"] = batch_structs(cfg, shape, mesh)
    else:
        out["caches"] = cache_structs(cfg, shape, mesh)
        out["token"] = jax.ShapeDtypeStruct(
            (shape.global_batch, 1), jnp.int32,
            sharding=NamedSharding(mesh, P(bspec, None)))
        # static cross/encoder inputs for decode already live in caches
    return out
