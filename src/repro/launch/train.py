"""Training driver: config -> mesh -> data -> jit'd train_step -> checkpointed
loop, with fault-tolerant restart and optional Homa-scheduled gradient sync.

CPU-runnable end to end with reduced configs:

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --smoke \
        --steps 40 --ckpt-dir /tmp/ckpt [--resume] [--crash-at 20] \
        [--grad-sync homa|naive] [--compress int8]

On a real cluster the same driver runs the full config against
make_production_mesh(); the dry-run (launch/dryrun.py) proves those cells
lower+compile.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.configs.reduced import reduced_config
from repro.models import model as M
from repro.models.params import init_params
from repro.training.optimizer import OptConfig, init_opt_state
from repro.training.step import build_train_step
from repro.checkpoint.store import CheckpointStore
from repro.data.pipeline import DataConfig, SyntheticLM, Prefetcher
from repro.distrib import homa_collectives as HC


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--crash-at", type=int, default=None,
                    help="simulate preemption: exit(17) after this step")
    ap.add_argument("--grad-sync", choices=["pjit", "homa", "naive"],
                    default="pjit")
    ap.add_argument("--compress", choices=["int8"], default=None)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.smoke else get_config(args.arch)
    shape = ShapeConfig("cli", args.seq_len, args.batch, "train")
    oc = OptConfig(lr=args.lr, warmup_steps=5, total_steps=args.steps,
                   weight_decay=0.01)

    params = init_params(M.model_defs(cfg), jax.random.key(args.seed))
    opt_state = init_opt_state(params, oc)
    start_step = 0

    store = None
    if args.ckpt_dir:
        store = CheckpointStore(args.ckpt_dir, keep=3)
        if args.resume and store.latest_step() is not None:
            (params, opt_state), start_step = store.restore(
                (params, opt_state))
            print(f"[train] resumed from step {start_step}")

    dc = DataConfig(seq_len=args.seq_len, global_batch=args.batch,
                    vocab_size=cfg.vocab_size, seed=args.seed)
    source = SyntheticLM(dc)
    prefetch = Prefetcher(source, start_step)

    if args.grad_sync in ("homa", "naive"):
        from repro.launch.mesh import make_host_mesh
        from repro.training.optimizer import adamw_update
        mesh = make_host_mesh()
        sync_cfg = HC.SyncConfig(chunk_bytes=1 << 16,
                                 compress=args.compress,
                                 srpt=args.grad_sync == "homa",
                                 overcommit=7 if args.grad_sync == "homa"
                                 else 1)

        def loss_fn(p, b):
            return M.loss_fn(cfg, p, b)[0]

        def opt_update(p, g, s):
            return adamw_update(p, g, s, oc)

        step_fn = HC.build_dp_train_step(loss_fn, opt_update, mesh,
                                         sync_cfg)
        err_state = HC.init_err_state(params, sync_cfg)

        def run_step(params, opt_state, batch):
            nonlocal err_state
            params, opt_state, metrics, err_state = step_fn(
                params, opt_state, batch, err_state)
            return params, opt_state, metrics
    else:
        ts = build_train_step(cfg, oc, grad_accum=1)
        jts = jax.jit(ts, donate_argnums=(0, 1))

        def run_step(params, opt_state, batch):
            return jts(params, opt_state, batch)

    losses = []
    t0 = time.time()
    step = start_step
    try:
        while step < args.steps:
            dstep, batch = prefetch.next()
            assert dstep == step, (dstep, step)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt_state, metrics = run_step(params, opt_state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            step += 1
            if step % args.log_every == 0 or step == args.steps:
                dt = (time.time() - t0) / max(step - start_step, 1)
                print(f"[train] step {step} loss {loss:.4f} "
                      f"gnorm {float(metrics.get('grad_norm', 0)):.3f} "
                      f"{dt * 1e3:.0f} ms/step", flush=True)
            if store and step % args.ckpt_every == 0:
                store.save(step, (params, opt_state))
            if args.crash_at is not None and step >= args.crash_at:
                print(f"[train] simulated preemption at step {step}")
                if store:
                    store.wait()
                prefetch.close()
                sys.exit(17)
    finally:
        if store:
            store.wait()
        prefetch.close()

    result = {"final_loss": losses[-1] if losses else None,
              "first_loss": losses[0] if losses else None,
              "steps": step}
    print(f"[train] done: {result}")
    return result


if __name__ == "__main__":
    main()
