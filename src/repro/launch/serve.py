"""Serving driver: Homa-SRPT continuous batching over a model's decode step.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --smoke \
        --requests 64 --batch-size 4 [--no-srpt]

Reports per-request slowdown (paper's metric: completion time / ideal time)
for the SRPT scheduler; `--no-srpt` runs the FIFO ("Basic") ablation.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.reduced import reduced_config
from repro.models import model as M
from repro.models.params import init_params
from repro.serving.scheduler import HomaScheduler, SchedulerConfig, Request


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--overcommit", type=int, default=7)
    ap.add_argument("--no-srpt", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.smoke else get_config(args.arch)
    params = init_params(M.model_defs(cfg), jax.random.key(args.seed))
    C = args.batch_size
    sched = HomaScheduler(SchedulerConfig(
        batch_size=C, overcommit=args.overcommit,
        srpt=not args.no_srpt))

    shapes = M.cache_shapes(cfg, C, 8)
    caches = jax.tree.map(lambda s: jnp.zeros(s, jnp.bfloat16), shapes,
                          is_leaf=lambda x: isinstance(x, tuple))
    state = {"caches": caches, "tokens": jnp.zeros((C, 1), jnp.int32)}
    decode = jax.jit(lambda p, c, t: M.forward_decode(cfg, p, t, 4, c))

    rng = np.random.default_rng(args.seed)
    # open-loop Poisson arrivals, heavy-tailed decode lengths (W-like)
    sizes = np.exp(rng.uniform(np.log(2), np.log(200),
                               args.requests)).astype(int)
    arrivals = np.cumsum(rng.exponential(3.0, args.requests))

    def decode_fn(batch):
        logits, deltas = decode(params, state["caches"], state["tokens"])
        state["caches"] = jax.tree.map(
            lambda o, n: n.astype(o.dtype), state["caches"], deltas)
        state["tokens"] = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        return [r.remaining <= 1 for r in batch]

    t, nxt, steps = 0.0, 0, 0
    t0 = time.time()
    while nxt < args.requests or sched.active or sched.queue:
        while nxt < args.requests and arrivals[nxt] <= t:
            sched.submit(Request(rid=nxt, prompt_len=4,
                                 max_new_tokens=int(sizes[nxt]),
                                 arrival=t))
            nxt += 1
        sched.step(decode_fn, t)
        t += 1.0
        steps += 1
        if steps > 100_000:
            break

    sl = sched.slowdowns()
    out = {"served": len(sched.finished), "steps": steps,
           "mean_slowdown": float(sl.mean()) if len(sl) else None,
           "p99_slowdown": float(np.percentile(sl, 99)) if len(sl) else None,
           "wall_s": round(time.time() - t0, 1)}
    print(f"[serve] {out}")
    return out


if __name__ == "__main__":
    main()
