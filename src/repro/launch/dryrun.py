import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
record memory/cost/collective artifacts for the roofline analysis.

The two lines above MUST run before any jax import (jax locks the device
count on first init); do not move them. Run one cell:

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b \
        --shape train_4k [--multi-pod]

or the full sweep (spawns one subprocess per cell so compiles are isolated):

    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of all shapes in an HLO result-type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved by collectives, from optimized HLO.

    For each collective op we count the *result* shape bytes (an upper bound
    on per-device wire traffic; for all-reduce it equals 2x(n-1)/n of the
    ring cost which we fold into the link-bandwidth constant)."""
    out = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.startswith("%") or s.startswith("ROOT"):
            m = re.search(r"=\s*(.+?)\s+(%?[\w-]+)\(", s)
            if not m:
                continue
            result_type, opname = m.group(1), m.group(2).lstrip("%")
            base = opname.split(".")[0]
            # strip "-start"/"-done" async suffixes
            for k in _COLLECTIVES:
                if base == k or base == k + "-start":
                    out[k]["count"] += 1
                    out[k]["bytes"] += _shape_bytes(result_type)
                    break
    out["total_bytes"] = sum(v["bytes"] for v in out.values()
                             if isinstance(v, dict))
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             unroll: bool = False, nblocks: int | None = None,
             mem_opt: bool = False, accum: int | None = None) -> dict:
    import jax
    from repro.configs import get_config, SHAPES, cell_is_skipped
    from repro.launch.mesh import make_production_mesh
    from repro.launch.inputs import input_specs
    from repro.training.optimizer import OptConfig
    from repro.training.step import build_train_step, build_serve_step, \
        build_prefill_step
    from repro.distrib import sharding as SH
    from repro.models import model as M

    skip = cell_is_skipped(arch, shape_name)
    if skip:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "reason": skip, "unroll": unroll}

    cfg = get_config(arch)
    if nblocks is not None:
        # depth-reduced variant for linear extrapolation of per-layer cost:
        # totals are affine in the number of scan blocks (see roofline.py)
        import dataclasses
        cfg = dataclasses.replace(
            cfg, num_layers=cfg.first_dense_layers
            + nblocks * cfg.block_period)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()

    specs = input_specs(cfg, shape, mesh)
    notes = SH.check_divisibility(cfg, mesh, shape)

    if shape.kind == "train":
        import jax.numpy as jnp
        oc = OptConfig(state_dtype=jnp.bfloat16) if mem_opt else OptConfig()
        # unroll: unrolled layer + accumulation scans (production microbatch
        # count) so cost_analysis counts every layer, microbatch, collective
        step = build_train_step(cfg, oc, mesh=mesh, shape=shape,
                                unroll=unroll, grad_accum=accum,
                                accum_dtype=jnp.bfloat16 if mem_opt
                                else jnp.float32)
        from repro.launch.inputs import opt_state_structs
        specs["opt_state"] = opt_state_structs(cfg, mesh, oc)
        args = (specs["params"], specs["opt_state"], specs["batch"])
    elif shape.kind == "prefill":
        step = build_prefill_step(cfg, mesh=mesh, shape=shape, unroll=unroll)
        args = (specs["params"], specs["batch"])
    else:  # decode
        step = build_serve_step(cfg, pos=shape.seq_len - 1, unroll=unroll)
        args = (specs["params"], specs["caches"], specs["token"], 0)

    with mesh:
        lowered = jax.jit(step, static_argnums=(3,) if shape.kind == "decode"
                          else ()).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                mem[k] = int(v)
    except Exception as e:  # noqa: BLE001
        mem["error"] = str(e)

    cost = {}
    try:
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
        for k, v in ca.items():
            if k in ("flops", "bytes accessed", "transcendentals") or \
                    k.startswith("bytes accessed"):
                cost[k] = float(v)
    except Exception as e:  # noqa: BLE001
        cost["error"] = str(e)

    hlo = compiled.as_text()
    coll = parse_collective_bytes(hlo)

    n_chips = mesh.devices.size
    n_params = M.count_model_params(cfg)
    n_active = M.active_params(cfg)

    res = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "multi_pod": multi_pod, "status": "ok", "unroll": unroll,
        "mem_opt": mem_opt,
        "n_chips": n_chips,
        "n_params": n_params, "n_active_params": n_active,
        "tokens_per_step": shape.global_batch * (1 if shape.is_decode
                                                 else shape.seq_len),
        "kind": shape.kind, "nblocks": nblocks,
        "n_scan_blocks_full": (get_config(arch).num_layers
                               - get_config(arch).first_dense_layers)
        // get_config(arch).block_period,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem, "cost": cost, "collectives": coll,
        "sharding_notes": notes,
        "hlo_bytes": len(hlo),
    }
    return res


def cell_path(arch: str, shape: str, multi_pod: bool,
              unroll: bool = False, nblocks: int | None = None,
              mem_opt: bool = False, accum: int | None = None) -> Path:
    mesh = "2x16x16" if multi_pod else "16x16"
    sfx = "__unrolled" if unroll else ""
    if nblocks is not None:
        sfx += f"__nb{nblocks}"
    if mem_opt:
        sfx += "__memopt"
    if accum is not None:
        sfx += f"__acc{accum}"
    return ARTIFACT_DIR / f"{arch}__{shape}__{mesh}{sfx}.json"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="measurement mode: unrolled scans, accum=1")
    ap.add_argument("--nblocks", type=int, default=None,
                    help="depth-reduced variant (for extrapolation)")
    ap.add_argument("--mem-opt", action="store_true",
                    help="bf16 optimizer states + bf16 grad accumulation")
    ap.add_argument("--accum", type=int, default=None,
                    help="override microbatch count")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true",
                    help="recompute cached cells")
    ap.add_argument("--timeout", type=int, default=3000)
    args = ap.parse_args()
    ARTIFACT_DIR.mkdir(parents=True, exist_ok=True)

    if args.all:
        import subprocess
        from repro.configs import ARCH_NAMES
        from repro.configs.base import SHAPES
        cells = [(a, s, mp) for a in ARCH_NAMES for s in SHAPES
                 for mp in (False, True)]
        failures = []
        for a, s, mp in cells:
            out = cell_path(a, s, mp)
            if out.exists() and not args.force:
                print(f"[cached] {out.name}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", a, "--shape", s] + (["--multi-pod"] if mp else [])
            print(f"[run] {a} x {s} x {'2x16x16' if mp else '16x16'}",
                  flush=True)
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout,
                               env={**os.environ, "PYTHONPATH": "src"})
            if r.returncode != 0:
                failures.append((a, s, mp, r.stderr[-2000:]))
                print(r.stderr[-2000:])
        print(f"done; {len(failures)} failures")
        for f in failures:
            print("FAIL:", f[:3])
        sys.exit(1 if failures else 0)

    try:
        res = run_cell(args.arch, args.shape, args.multi_pod, args.unroll,
                       args.nblocks, args.mem_opt, args.accum)
    except Exception:  # noqa: BLE001
        res = {"arch": args.arch, "shape": args.shape,
               "multi_pod": args.multi_pod, "status": "error",
               "traceback": traceback.format_exc()}
    out = cell_path(args.arch, args.shape, args.multi_pod, args.unroll,
                    args.nblocks, args.mem_opt, args.accum)
    out.write_text(json.dumps(res, indent=2))
    if res["status"] == "ok":
        print(json.dumps({k: res[k] for k in
                          ("arch", "shape", "mesh", "compile_s", "cost",
                           "memory")}, indent=2))
        print("collective bytes/device:", res["collectives"]["total_bytes"])
    else:
        print(json.dumps(res, indent=2))
        if res["status"] == "error":
            sys.exit(1)


if __name__ == "__main__":
    main()
