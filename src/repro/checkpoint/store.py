"""Fault-tolerant checkpointing: sharded msgpack+zstd, atomic, async, keep-k,
with elastic reshard-on-restore.

Layout:  <dir>/step_<N>/
            meta.json              step, config digest, tree structure
            shard_<host>.msgpack.zst   this host's param/opt leaves
            COMMIT                 written last: a checkpoint without it is
                                   ignored (atomic via rename of tmpdir)

Every leaf is saved as host-local numpy (addressable shards concatenated on
restore if the topology changed — elastic scaling). On a single-process CPU
run there is one shard; the format is identical.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import msgpack
import numpy as np
import zstandard


def _pack_array(a: np.ndarray) -> dict:
    return {"dtype": str(a.dtype) if a.dtype != jnp.bfloat16 else "bfloat16",
            "shape": list(a.shape),
            "data": (a.view(np.uint16) if a.dtype == jnp.bfloat16
                     else a).tobytes()}


def _unpack_array(d: dict) -> np.ndarray:
    if d["dtype"] == "bfloat16":
        raw = np.frombuffer(d["data"], np.uint16).reshape(d["shape"])
        return raw.view(jnp.bfloat16)
    return np.frombuffer(d["data"], np.dtype(d["dtype"])).reshape(d["shape"])


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class CheckpointStore:
    def __init__(self, directory: str | os.PathLike, keep: int = 3,
                 async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    # ------------------------------------------------------------- save ----
    def save(self, step: int, tree, *, block: bool = False):
        """Snapshot to host memory synchronously, write to disk (optionally
        in a background thread), commit atomically."""
        self.wait()                                   # one in flight at most
        host_leaves = [np.asarray(x) for x in jax.tree.leaves(tree)]
        treedef = jax.tree.structure(tree)

        def write():
            try:
                tmp = self.dir / f".tmp_step_{step}_{os.getpid()}"
                if tmp.exists():
                    shutil.rmtree(tmp)
                tmp.mkdir(parents=True)
                cctx = zstandard.ZstdCompressor(level=3)
                payload = msgpack.packb(
                    [_pack_array(a) for a in host_leaves])
                (tmp / "shard_0.msgpack.zst").write_bytes(
                    cctx.compress(payload))
                (tmp / "meta.json").write_text(json.dumps({
                    "step": step, "n_leaves": len(host_leaves),
                    "treedef": str(treedef), "time": time.time()}))
                (tmp / "COMMIT").write_text("ok")
                final = self.dir / f"step_{step}"
                if final.exists():
                    shutil.rmtree(final)
                tmp.rename(final)
                self._gc()
            except Exception as e:  # noqa: BLE001
                self._error = e

        if self.async_save and not block:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()
            if self._error:
                raise self._error

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error:
            e, self._error = self._error, None
            raise e

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # ---------------------------------------------------------- restore ----
    def steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "COMMIT").exists():
                try:
                    out.append(int(p.name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, tree_like, step: int | None = None, *,
                shardings=None):
        """Restore into the structure of `tree_like`. If `shardings` (a
        matching pytree of NamedSharding) is given, leaves are placed with
        jax.device_put per sharding — this is the elastic path: the same
        checkpoint restores onto any mesh."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        d = self.dir / f"step_{step}"
        dctx = zstandard.ZstdDecompressor()
        payload = msgpack.unpackb(
            dctx.decompress((d / "shard_0.msgpack.zst").read_bytes()))
        arrays = [_unpack_array(x) for x in payload]
        leaves, treedef = jax.tree.flatten(tree_like)
        assert len(arrays) == len(leaves), "checkpoint/tree mismatch"
        if shardings is not None:
            shard_leaves = jax.tree.leaves(shardings)
            arrays = [jax.device_put(a, s)
                      for a, s in zip(arrays, shard_leaves)]
        else:
            arrays = [jnp.asarray(a) for a in arrays]
        return jax.tree.unflatten(treedef, arrays), step
