"""Pure-jnp oracles for the arbitration kernels (the same math the simulator
uses inline)."""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

BIG = jnp.int32(2 ** 30)


def priority_arbiter_ref(prio, seq, elig):
    """Strict-priority, FIFO-within-level selection per row.
    Returns (best_prio (H,), best_idx (H,))."""
    p = jnp.where(elig, prio, BIG)
    s = jnp.where(elig, seq, BIG)
    pmin = p.min(axis=1)
    s_cand = jnp.where(p == pmin[:, None], s, BIG)
    idx = jnp.argmin(s_cand, axis=1).astype(jnp.int32)
    return pmin, idx


NEG = jnp.int32(-(2 ** 30))   # ineligible key sentinel (see kernel.py)


def srpt_topk_ref(keys, K: int):
    """K largest keys per row plus their source columns.
    Returns ``(vals (H, K), idx (H, K))``: descending keys clamped at 0,
    columns -1 where fewer than K positive keys exist. Short rows pad
    with the ``NEG`` sentinel — not zero, which is a legitimate
    (ineligible) key value that must still outrank padding."""
    if keys.shape[1] < K:
        keys = jnp.pad(keys, ((0, 0), (0, K - keys.shape[1])),
                       constant_values=NEG)
    vals, idx = lax.top_k(keys, K)
    return (jnp.maximum(vals, 0).astype(jnp.int32),
            jnp.where(vals > 0, idx.astype(jnp.int32), -1))
