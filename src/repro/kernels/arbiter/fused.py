"""Fused per-slot arbitration mega-kernel (DESIGN.md §11).

One ``pallas_call`` per simulated slot covering all three arbitration
stages that ``dispatch.py`` previously issued as separate kernels:

  downlink drain   lexicographic (prio, seq) argmin over the receiver
                   rings — the math of ``kernel.priority_arbiter``
  uplink drain     the same argmin over the TOR uplink rings (leaf-spine
                   fabrics only)
  SRPT grant set   per-receiver top-K keys + source columns — the math
                   of ``kernel.srpt_topk``

The three stages are data-independent within a slot once hoisted to slot
start (the sim enforces the delay preconditions that make the hoist
bit-exact — see ``sim._fused_precompute`` and DESIGN.md §11), so the
kernel simply runs them back to back on whole-array VMEM blocks: at
simulator scale every operand fits VMEM comfortably, and fusing removes
two of the three HBM round-trips plus two kernel launches per slot.

Each stage's math is the single-block execution of the corresponding
staged kernel — same masked reductions, same first-occurrence tie
breaks, same ``BIG``/``NEG`` sentinels — which is why fused == staged is
bit-exact and not merely close (the reductions are reordered across
*blocks*, never within a row).

Two entry points:

  ``fused_slot(...)``        single slot; inputs are pre-padded 2-D tiles
  ``fused_slot_batch(...)``  leading batch axis (one sweep-run per grid
                             program): ``grid=(B,)`` so a vmapped sweep
                             issues ONE kernel launch per slot for the
                             whole run batch instead of B

``fused_slot`` carries a ``jax.custom_batching.custom_vmap`` rule that
rewrites ``vmap(fused_slot)`` into ``fused_slot_batch`` — the chunked
sweep path (``repro.core.sweep``) gets the batched launch for free, with
unbatched operands broadcast. Padding/shape policy lives in
``dispatch.fused_slot``; these entry points require exact tile multiples.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.arbiter.kernel import BIG, NEG


# ----------------------------------------------------- stage primitives ----

def _lex_argmin(prio, seq, elig):
    """Single-block ``_arb_kernel`` math: strict-priority-then-FIFO winner
    per row. Returns ``(best_prio, best_idx)``; ``(BIG, 0)`` when the row
    has no eligible entry."""
    p = jnp.where(elig, prio, BIG)
    s = jnp.where(elig, seq, BIG)
    pmin = jnp.min(p, axis=1)
    s_cand = jnp.where(p == pmin[:, None], s, BIG)
    idx = jnp.argmin(s_cand, axis=1).astype(jnp.int32)
    return pmin, idx


def _topk_rounds(keys, K: int):
    """Single-block ``_topk_kernel`` math: K rounds of masked max with
    first-occurrence extraction. The running-tops prefix sits before the
    key columns exactly as in the staged kernel's concat, so tie-breaks
    (lowest global column — ``lax.top_k`` stability) are identical."""
    Hb, Mb = keys.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (Hb, Mb), 1)
    cand_v = jnp.concatenate(
        [jnp.full((Hb, K), NEG, jnp.int32), keys], axis=1)
    cand_i = jnp.concatenate(
        [jnp.full((Hb, K), -1, jnp.int32), col], axis=1)
    tops_v = jnp.full((Hb, K), NEG, jnp.int32)
    tops_i = jnp.full((Hb, K), -1, jnp.int32)
    for r in range(K):
        m = jnp.max(cand_v, axis=1)
        is_m = cand_v == m[:, None]
        first = is_m & (jnp.cumsum(is_m.astype(jnp.int32), axis=1) == 1)
        tops_v = tops_v.at[:, r].set(m)
        tops_i = tops_i.at[:, r].set(
            jnp.max(jnp.where(first, cand_i, -1), axis=1))
        cand_v = jnp.where(first, jnp.int32(NEG), cand_v)
        cand_i = jnp.where(first, jnp.int32(-1), cand_i)
    return tops_v, tops_i


# ------------------------------------------------------------ the kernel ---

def _fused_kernel(*refs, K: int, has_down: bool, has_up: bool,
                  has_topk: bool, batched: bool):
    """(*ins, *outs) refs in stage order. ``batched`` refs carry a
    leading length-1 block axis (one grid program per batch element)."""
    rd = (lambda r: r[0]) if batched else (lambda r: r[...])

    def wr(r, v):
        if batched:
            r[0] = v
        else:
            r[...] = v

    n_in = 3 * has_down + 3 * has_up + has_topk
    ins, outs = refs[:n_in], refs[n_in:]
    i = o = 0
    if has_down:
        bp, bi = _lex_argmin(rd(ins[i]), rd(ins[i + 1]), rd(ins[i + 2]))
        wr(outs[o], bp)
        wr(outs[o + 1], bi)
        i += 3
        o += 2
    if has_up:
        bp, bi = _lex_argmin(rd(ins[i]), rd(ins[i + 1]), rd(ins[i + 2]))
        wr(outs[o], bp)
        wr(outs[o + 1], bi)
        i += 3
        o += 2
    if has_topk:
        tv, ti = _topk_rounds(rd(ins[i]), K)
        wr(outs[o], tv)
        wr(outs[o + 1], ti)


def _out_shapes(arrays, K: int, has_down: bool, has_up: bool,
                has_topk: bool):
    """Logical (unbatched) output shapes in stage order."""
    shapes = []
    i = 0
    if has_down:
        H = arrays[i].shape[-2]
        shapes += [(H,), (H,)]
        i += 3
    if has_up:
        U = arrays[i].shape[-2]
        shapes += [(U,), (U,)]
        i += 3
    if has_topk:
        H2 = arrays[i].shape[-2]
        shapes += [(H2, K), (H2, K)]
    return shapes


def _call_single(arrays, K, has_down, has_up, has_topk, interpret):
    kernel = functools.partial(_fused_kernel, K=K, has_down=has_down,
                               has_up=has_up, has_topk=has_topk,
                               batched=False)
    out_shape = [jax.ShapeDtypeStruct(s, jnp.int32)
                 for s in _out_shapes(arrays, K, has_down, has_up,
                                      has_topk)]
    # no grid: one program, whole-array VMEM refs — dispatch.fused_slot
    # guarantees the operands fit (falls back to staged kernels otherwise)
    return pl.pallas_call(kernel, out_shape=out_shape,
                          interpret=interpret)(*arrays)


def _call_batch(arrays, K, has_down, has_up, has_topk, interpret):
    B = arrays[0].shape[0]
    kernel = functools.partial(_fused_kernel, K=K, has_down=has_down,
                               has_up=has_up, has_topk=has_topk,
                               batched=True)

    def spec(shape):
        return pl.BlockSpec((1,) + shape,
                            lambda b, nd=len(shape): (b,) + (0,) * nd)

    shapes = _out_shapes(arrays, K, has_down, has_up, has_topk)
    return pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[spec(a.shape[1:]) for a in arrays],
        out_specs=[spec(s) for s in shapes],
        out_shape=[jax.ShapeDtypeStruct((B,) + s, jnp.int32)
                   for s in shapes],
        interpret=interpret,
    )(*arrays)


@functools.lru_cache(maxsize=None)
def _fused_fn(K: int, has_down: bool, has_up: bool, has_topk: bool,
              interpret: bool):
    """Cached custom-vmap callable for one static stage structure.
    Calling it plain runs the single-slot kernel; under ``vmap`` (the
    sweep paths) the rule below swaps in the ``grid=(B,)`` batched
    variant — one launch per slot for the whole run batch."""

    @jax.custom_batching.custom_vmap
    def fn(*arrays):
        return tuple(_call_single(arrays, K, has_down, has_up, has_topk,
                                  interpret))

    @fn.def_vmap
    def _rule(axis_size, in_batched, *arrays):  # noqa: ANN001
        arrays = tuple(
            a if b else jnp.broadcast_to(a, (axis_size,) + a.shape)
            for a, b in zip(arrays, in_batched))
        outs = tuple(_call_batch(arrays, K, has_down, has_up, has_topk,
                                 interpret))
        return outs, tuple(True for _ in outs)

    return fn


# ---------------------------------------------------------- entry points ---

def fused_slot(down=None, up=None, keys=None, K: int = 0, *,
               interpret: bool = False):
    """One fused arbitration slot. All operands pre-padded to exact TPU
    tile multiples (rows→8, cols→128 — ``dispatch.pad_tiles``):

      down/up  ``(prio, seq, elig)`` with ``BIG``/``BIG``/``False`` pads
      keys     ``(H, M)`` int32 top-K keys, ``NEG``-padded, with ``K`` ≥ 1

    Returns raw per-stage outputs in stage order:
    ``[d_prio, d_idx][, u_prio, u_idx][, vals, idx]`` — the same raw
    convention as ``kernel.priority_arbiter`` / ``kernel.srpt_topk``
    (callers normalize). Under ``vmap`` this dispatches the batched
    ``grid=(B,)`` variant via ``custom_vmap``."""
    arrays = []
    if down is not None:
        arrays += list(down)
    if up is not None:
        arrays += list(up)
    if keys is not None:
        arrays.append(keys)
    fn = _fused_fn(K, down is not None, up is not None, keys is not None,
                   interpret)
    return fn(*arrays)


def fused_slot_batch(down=None, up=None, keys=None, K: int = 0, *,
                     interpret: bool = False):
    """Explicit batched variant: every operand carries a leading batch
    axis and the kernel runs with ``grid=(B,)`` — one program per batch
    element, one launch total. Same raw output convention as
    :func:`fused_slot` with the batch axis prepended."""
    arrays = []
    if down is not None:
        arrays += list(down)
    if up is not None:
        arrays += list(up)
    if keys is not None:
        arrays.append(keys)
    return tuple(_call_batch(tuple(arrays), K, down is not None,
                             up is not None, keys is not None, interpret))


__all__ = ["fused_slot", "fused_slot_batch"]
