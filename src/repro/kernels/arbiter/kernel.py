"""Homa switch-arbitration Pallas TPU kernels — the simulator's per-slot hot
spots, TPU-ified (DESIGN.md §5): the "switch egress port" becomes a
vectorized arbitration kernel over the chunk buffer.

1. ``priority_arbiter``: per receiver row, select the buffered chunk to drain:
   strict priority, FIFO (insertion sequence) within a level. Lexicographic
   masked argmin over (prio, seq), tiled over buffer blocks with the running
   best carried in VMEM scratch.

2. ``srpt_topk``: per receiver row, the K messages with the best (largest)
   key — Homa's overcommitment grant set (top-K SRPT). Iterated masked max
   with running top-K value AND column registers in scratch, so the grant
   path gets the winning message ids directly (no re-matching scan).

Padding/block-size selection lives in ``dispatch.py``; these raw kernels
require exact block multiples.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BIG = 2 ** 30    # plain int: jnp constants would be captured as kernel operands
NEG = -(2 ** 30)  # ineligible key sentinel: below every legitimate key (>= 0)


# ------------------------------------------------------ priority arbiter ---

def _arb_kernel(prio_ref, seq_ref, elig_ref, prio_out, idx_out,
                bp_scr, bs_scr, bi_scr, *, bc: int, ncap: int):
    # NB pallas binds (*ins, *outs, *scratch) — outputs before scratch
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        bp_scr[...] = jnp.full_like(bp_scr, BIG)
        bs_scr[...] = jnp.full_like(bs_scr, BIG)
        bi_scr[...] = jnp.zeros_like(bi_scr)

    elig = elig_ref[...]
    p = jnp.where(elig, prio_ref[...], BIG)                 # (bh, bc)
    s = jnp.where(elig, seq_ref[...], BIG)

    # local lexicographic argmin within the block
    pmin = jnp.min(p, axis=1)                               # (bh,)
    s_cand = jnp.where(p == pmin[:, None], s, BIG)
    smin = jnp.min(s_cand, axis=1)
    col = jnp.argmin(s_cand, axis=1).astype(jnp.int32) + ci * bc

    # merge with running best
    bp, bs = bp_scr[...], bs_scr[...]
    better = (pmin < bp) | ((pmin == bp) & (smin < bs))
    bp_scr[...] = jnp.where(better, pmin, bp)
    bs_scr[...] = jnp.where(better, smin, bs)
    bi_scr[...] = jnp.where(better, col, bi_scr[...])

    @pl.when(ci == ncap - 1)
    def _fin():
        prio_out[...] = bp_scr[...]
        idx_out[...] = bi_scr[...]


def priority_arbiter(prio, seq, elig, *, block_h: int = 8,
                     block_c: int = 256, interpret: bool = False):
    """prio/seq: (H, cap) int32; elig: (H, cap) bool.
    Returns (best_prio (H,), best_idx (H,)); best_prio == BIG if none."""
    H, cap = prio.shape
    bh = min(block_h, H)
    bc = min(block_c, cap)
    assert H % bh == 0 and cap % bc == 0
    ncap = cap // bc

    kernel = functools.partial(_arb_kernel, bc=bc, ncap=ncap)
    return pl.pallas_call(
        kernel,
        grid=(H // bh, ncap),
        in_specs=[pl.BlockSpec((bh, bc), lambda hi, ci: (hi, ci)),
                  pl.BlockSpec((bh, bc), lambda hi, ci: (hi, ci)),
                  pl.BlockSpec((bh, bc), lambda hi, ci: (hi, ci))],
        out_specs=[pl.BlockSpec((bh,), lambda hi, ci: (hi,)),
                   pl.BlockSpec((bh,), lambda hi, ci: (hi,))],
        out_shape=[jax.ShapeDtypeStruct((H,), jnp.int32),
                   jax.ShapeDtypeStruct((H,), jnp.int32)],
        # NB: distinct scratch objects — a repeated instance would alias
        scratch_shapes=[pltpu.VMEM((bh,), jnp.int32),
                        pltpu.VMEM((bh,), jnp.int32),
                        pltpu.VMEM((bh,), jnp.int32)],
        interpret=interpret,
    )(prio, seq, elig)


# ---------------------------------------------------------- SRPT top-K -----

def _topk_kernel(key_ref, val_out, idx_out, val_scr, idx_scr, *,
                 K: int, bm: int, nm: int):
    mi = pl.program_id(1)

    @pl.when(mi == 0)
    def _init():
        val_scr[...] = jnp.full_like(val_scr, NEG)
        idx_scr[...] = jnp.full_like(idx_scr, -1)

    k = key_ref[...]                                        # (bh, bm) int32
    bh = k.shape[0]
    col = (jax.lax.broadcasted_iota(jnp.int32, (bh, bm), 1)
           + mi * bm)                                       # global columns
    # merge block into running top-K: combine candidates, extract K maxima.
    # NEG is the neutral "taken/absent" sentinel — NOT zero, which is a
    # legitimate (ineligible) key value that must still outrank padding.
    # Extraction takes the FIRST occurrence of each maximum; running tops
    # sit before block columns in the concat and block columns ascend, so
    # ties resolve to the lowest global column — lax.top_k's stability.
    cand_v = jnp.concatenate([val_scr[...], k], axis=1)     # (bh, K+bm)
    cand_i = jnp.concatenate([idx_scr[...], col], axis=1)
    tops_v, tops_i = val_scr[...], idx_scr[...]
    for r in range(K):
        m = jnp.max(cand_v, axis=1)                         # (bh,)
        is_m = cand_v == m[:, None]
        first = is_m & (jnp.cumsum(is_m.astype(jnp.int32), axis=1) == 1)
        tops_v = tops_v.at[:, r].set(m)
        tops_i = tops_i.at[:, r].set(
            jnp.max(jnp.where(first, cand_i, -1), axis=1))
        cand_v = jnp.where(first, jnp.int32(NEG), cand_v)
        cand_i = jnp.where(first, jnp.int32(-1), cand_i)

    val_scr[...] = tops_v
    idx_scr[...] = tops_i

    @pl.when(mi == nm - 1)
    def _fin():
        val_out[...] = val_scr[...]
        idx_out[...] = idx_scr[...]


def srpt_topk(keys, K: int, *, block_h: int = 8, block_m: int = 512,
              interpret: bool = False):
    """keys: (H, M) int32, 0 = ineligible, larger = more urgent.
    Returns raw ``(vals (H, K), idx (H, K))`` int32: the K largest keys
    per row in descending order plus their source columns. Rows with
    fewer than K entries carry the ``NEG`` sentinel / -1 — callers
    normalize (``dispatch.pallas_topk`` clamps vals at 0 and masks idx)."""
    H, M = keys.shape
    bh = min(block_h, H)
    bm = min(block_m, M)
    assert H % bh == 0 and M % bm == 0
    nm = M // bm

    kernel = functools.partial(_topk_kernel, K=K, bm=bm, nm=nm)
    return pl.pallas_call(
        kernel,
        grid=(H // bh, nm),
        in_specs=[pl.BlockSpec((bh, bm), lambda hi, mi: (hi, mi))],
        out_specs=[pl.BlockSpec((bh, K), lambda hi, mi: (hi, 0)),
                   pl.BlockSpec((bh, K), lambda hi, mi: (hi, 0))],
        out_shape=[jax.ShapeDtypeStruct((H, K), jnp.int32),
                   jax.ShapeDtypeStruct((H, K), jnp.int32)],
        # NB: distinct scratch objects — a repeated instance would alias
        scratch_shapes=[pltpu.VMEM((bh, K), jnp.int32),
                        pltpu.VMEM((bh, K), jnp.int32)],
        interpret=interpret,
    )(keys)
