"""Backend dispatch for the per-slot arbitration hot path (DESIGN.md §6).

One entry point per arbitration primitive, each routable to either
compute backend:

  ``arbitrate(prio, seq, elig, backend=...)``   strict-priority-then-FIFO
      winner per row — the math of ``fabric.ring_drain_select``.
  ``topk(keys, K, backend=...)``                per-row top-K (values AND
      source columns) — the receiver's SRPT grant-set selection.

``backend="reference"`` runs the pure-jnp oracles (``ref.py``);
``backend="pallas"`` runs the Pallas TPU kernels (``kernel.py``) through
the padded wrappers below. Both are bit-identical by contract — the
golden-snapshot tests in ``tests/test_backend.py`` and the property
tests in ``tests/test_kernels.py`` enforce it — so ``SimConfig.backend``
is a pure performance knob.

This module also owns the padding/block-size heuristics that used to be
duplicated per call site in ``ops.py``: rows pad to the 8-sublane
multiple, columns pad to the 128-lane multiple (the TPU tile for int32),
and the block size is the largest preferred power of two dividing the
padded dimension. Padding values are chosen so padded entries can never
win (``BIG`` priority / ``False`` eligibility / the ``NEG`` key
sentinel — NOT zero, which is a legitimate key value).

Interpret-mode selection (``resolve_interpret``): Pallas TPU kernels
only compile on a TPU, so off-TPU the pallas backend auto-selects
``interpret=True`` — the kernel is traced into plain XLA ops and runs
(and is tested) everywhere. ``SIM_PALLAS_INTERPRET=0|1`` overrides, so
a TPU host can still benchmark the interpreted path.
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.arbiter.kernel import (priority_arbiter, srpt_topk,
                                          BIG, NEG)
from repro.kernels.arbiter.ref import priority_arbiter_ref, srpt_topk_ref

BACKENDS = ("reference", "pallas")
_ROW_UNIT = 8          # TPU sublane multiple for int32 blocks
_COL_UNIT = 128        # TPU lane multiple


def resolve_backend(name: str | None) -> str:
    """``None`` -> ``$SIM_BACKEND`` (default ``reference``); unknown
    names raise a ``ValueError`` listing the choices."""
    if name is None:
        name = os.environ.get("SIM_BACKEND") or "reference"
    if name not in BACKENDS:
        raise ValueError(f"unknown backend {name!r}; expected one of "
                         f"{list(BACKENDS)} (or $SIM_BACKEND)")
    return name


def resolve_interpret(interpret: bool | None) -> bool:
    """``None`` -> auto: interpret everywhere except on a real TPU,
    overridable via ``$SIM_PALLAS_INTERPRET``."""
    if interpret is not None:
        return interpret
    # empty string == unset, the same convention resolve_backend uses
    env = os.environ.get("SIM_PALLAS_INTERPRET")
    if env:
        return env.lower() not in ("0", "false")
    return jax.default_backend() != "tpu"


# ------------------------------------------------- padding heuristics ------

def _padded_dim(n: int, unit: int) -> int:
    return -(-n // unit) * unit


def _block(n_padded: int, preferred: int, unit: int) -> int:
    """Largest power-of-two multiple of ``unit`` that divides the padded
    dimension, capped at ``preferred`` (a power-of-two multiple of
    ``unit``). Never degenerates to one un-tiled block."""
    b = preferred
    while b > unit and n_padded % b:
        b //= 2
    return min(b, n_padded)


def _pad2(x, rows: int, cols: int, fill):
    """Pad a 2-D array up to (rows, cols) with ``fill``."""
    H, C = x.shape
    if rows == H and cols == C:
        return x
    return jnp.pad(x, ((0, rows - H), (0, cols - C)), constant_values=fill)


# ---------------------------------------------------- pallas wrappers ------

@partial(jax.jit, static_argnames=("interpret",))
def pallas_arbitrate(prio, seq, elig, *, interpret: bool = False):
    """Padded ``priority_arbiter`` call: returns ``(best_prio, best_idx)``
    per row, ``best_prio == BIG`` (and ``best_idx == 0``) if the row has
    no eligible entry — exactly ``ref.priority_arbiter_ref``."""
    H, cap = prio.shape
    Hp = _padded_dim(H, _ROW_UNIT)
    capp = _padded_dim(cap, _COL_UNIT)
    bh = _block(Hp, _ROW_UNIT, _ROW_UNIT)
    bc = _block(capp, 256, _COL_UNIT)
    pp = _pad2(prio, Hp, capp, BIG)
    sp = _pad2(seq, Hp, capp, BIG)
    ep = _pad2(elig, Hp, capp, False)
    bp, bi = priority_arbiter(pp, sp, ep, block_h=bh, block_c=bc,
                              interpret=interpret)
    return bp[:H], bi[:H]


@partial(jax.jit, static_argnames=("K", "interpret"))
def pallas_topk(keys, K: int, *, interpret: bool = False):
    """Padded ``srpt_topk`` call: returns ``(vals, idx)`` — the K largest
    keys per row (descending, clamped at 0) and their source columns
    (-1 where fewer than K positive keys exist). Columns pad with the
    ``NEG`` sentinel, never zero: 0 is a legitimate (ineligible) key
    value and must still outrank padding so indices stay in-bounds."""
    H, M = keys.shape
    if M < K:
        keys = jnp.pad(keys, ((0, 0), (0, K - M)), constant_values=NEG)
        M = K
    Hp = _padded_dim(H, _ROW_UNIT)
    Mp = _padded_dim(M, _COL_UNIT)
    bh = _block(Hp, _ROW_UNIT, _ROW_UNIT)
    bm = _block(Mp, 512, _COL_UNIT)
    kp = _pad2(keys, Hp, Mp, NEG)
    vals, idx = srpt_topk(kp, K, block_h=bh, block_m=bm,
                          interpret=interpret)
    vals, idx = vals[:H], idx[:H]
    return jnp.maximum(vals, 0), jnp.where(vals > 0, idx, -1)


# -------------------------------------------------------- dispatchers ------

def arbitrate(prio, seq, elig, *, backend: str = "reference",
              interpret: bool | None = None):
    """Strict-priority, FIFO-within-level winner per row on the chosen
    backend. Returns ``(best_prio (H,), best_idx (H,))``; rows with no
    eligible entry return ``(BIG, 0)``. Bit-identical across backends."""
    if resolve_backend(backend) == "reference":
        return priority_arbiter_ref(prio, seq, elig)
    return pallas_arbitrate(prio, seq, elig,
                            interpret=resolve_interpret(interpret))


def topk(keys, K: int, *, backend: str = "reference",
         interpret: bool | None = None):
    """Per-row top-K keys + source columns on the chosen backend.
    Returns ``(vals (H, K), idx (H, K))``: descending keys clamped at 0,
    columns -1 where fewer than K positive keys exist. Ties resolve to
    the lowest column on both backends (``lax.top_k`` stability)."""
    if resolve_backend(backend) == "reference":
        return srpt_topk_ref(keys, K)
    return pallas_topk(keys, K, interpret=resolve_interpret(interpret))


__all__ = ["BACKENDS", "resolve_backend", "resolve_interpret",
           "arbitrate", "topk", "pallas_arbitrate", "pallas_topk"]
