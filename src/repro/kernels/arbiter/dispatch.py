"""Backend dispatch for the per-slot arbitration hot path (DESIGN.md §6).

One entry point per arbitration primitive, each routable to either
compute backend:

  ``arbitrate(prio, seq, elig, backend=...)``   strict-priority-then-FIFO
      winner per row — the math of ``fabric.ring_drain_select``.
  ``topk(keys, K, backend=...)``                per-row top-K (values AND
      source columns) — the receiver's SRPT grant-set selection.
  ``fused_slot(down=..., up=..., topk=...)``    all of a slot's stages in
      ONE kernel launch — the ``pallas_fused`` backend's entry point
      (DESIGN.md §11), called from ``sim._fused_precompute``.

``backend="reference"`` runs the pure-jnp oracles (``ref.py``);
``backend="pallas"`` runs the Pallas TPU kernels (``kernel.py``) through
the padded wrappers below; ``backend="pallas_fused"`` additionally fuses
the three per-slot stages into one launch (``fused.py``) — the staged
primitives below still serve its non-fusable call sites. All backends
are bit-identical by contract — the golden-snapshot tests in
``tests/test_backend.py``, the differential fuzz harness in
``tests/test_differential.py``, and the property tests in
``tests/test_kernels.py`` enforce it — so ``SimConfig.backend`` is a
pure performance knob.

This module also owns the padding/block-size heuristics, shared by every
wrapper through :func:`pad_tiles`: rows pad to the 8-sublane multiple,
columns pad to the 128-lane multiple (the TPU tile for int32), and the
block size is the largest preferred power of two dividing the padded
dimension. Padding values are chosen so padded entries can never win
(``BIG`` priority / ``False`` eligibility / the ``NEG`` key sentinel —
NOT zero, which is a legitimate key value).

Interpret-mode selection (``resolve_interpret``): Pallas TPU kernels
only compile on a TPU, so off-TPU the pallas backends auto-select
``interpret=True`` — the kernel is traced into plain XLA ops and runs
(and is tested) everywhere. ``SIM_PALLAS_INTERPRET=0|1`` overrides, so
a TPU host can still benchmark the interpreted path.
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.arbiter.kernel import (priority_arbiter, srpt_topk,
                                          BIG, NEG)
from repro.kernels.arbiter.ref import priority_arbiter_ref, srpt_topk_ref
from repro.kernels.arbiter import fused as fused_mod

BACKENDS = ("reference", "pallas", "pallas_fused")
_ROW_UNIT = 8          # TPU sublane multiple for int32 blocks
_COL_UNIT = 128        # TPU lane multiple

# operand-size ceiling for the no-grid fused kernel (whole arrays live
# in VMEM simultaneously); beyond it dispatch falls back to the staged
# per-stage kernels — still pallas, still bit-identical
FUSED_VMEM_LIMIT_BYTES = 8 * 2 ** 20


def resolve_backend(name: str | None) -> str:
    """``None`` -> ``$SIM_BACKEND`` (default ``reference``); unknown
    names raise a ``ValueError`` listing the choices."""
    if name is None:
        name = os.environ.get("SIM_BACKEND") or "reference"
    if name not in BACKENDS:
        raise ValueError(f"unknown backend {name!r}; expected one of "
                         f"{list(BACKENDS)} (or $SIM_BACKEND)")
    return name


def resolve_interpret(interpret: bool | None) -> bool:
    """``None`` -> auto: interpret everywhere except on a real TPU,
    overridable via ``$SIM_PALLAS_INTERPRET``."""
    if interpret is not None:
        return interpret
    # empty string == unset, the same convention resolve_backend uses
    env = os.environ.get("SIM_PALLAS_INTERPRET")
    if env:
        return env.lower() not in ("0", "false")
    return jax.default_backend() != "tpu"


# ------------------------------------------------- padding heuristics ------

def _padded_dim(n: int, unit: int) -> int:
    return -(-n // unit) * unit


def _block(n_padded: int, preferred: int, unit: int) -> int:
    """Largest power-of-two multiple of ``unit`` that divides the padded
    dimension, capped at ``preferred`` (a power-of-two multiple of
    ``unit``). Never degenerates to one un-tiled block."""
    b = preferred
    while b > unit and n_padded % b:
        b //= 2
    return min(b, n_padded)


def _pad2(x, rows: int, cols: int, fill):
    """Pad a 2-D array up to (rows, cols) with ``fill``."""
    H, C = x.shape
    if rows == H and cols == C:
        return x
    return jnp.pad(x, ((0, rows - H), (0, cols - C)), constant_values=fill)


def pad_tiles(arrs, fills, *, col_pref: int = 256):
    """THE shared pad-and-tile policy (used by ``arbitrate``, ``topk``
    and the fused entry point): pad each same-shape 2-D array in ``arrs``
    to the TPU tile — rows to the 8-sublane multiple, columns to the
    128-lane multiple — with its own can't-win ``fill``, and pick block
    sizes (rows block 8; columns the largest power-of-two multiple of
    128 dividing the padded width, capped at ``col_pref``).

    Returns ``(padded_arrays, (block_h, block_c))``."""
    H, C = arrs[0].shape
    Hp = _padded_dim(H, _ROW_UNIT)
    Cp = _padded_dim(C, _COL_UNIT)
    bh = _block(Hp, _ROW_UNIT, _ROW_UNIT)
    bc = _block(Cp, col_pref, _COL_UNIT)
    return tuple(_pad2(a, Hp, Cp, f)
                 for a, f in zip(arrs, fills)), (bh, bc)


def pad_min_cols(keys, K: int):
    """Top-K inputs narrower than K widen to K columns with the ``NEG``
    sentinel — never zero: 0 is a legitimate (ineligible) key value and
    must still outrank padding so indices stay in-bounds."""
    H, M = keys.shape
    if M < K:
        keys = jnp.pad(keys, ((0, 0), (0, K - M)), constant_values=NEG)
    return keys


# ---------------------------------------------------- pallas wrappers ------

@partial(jax.jit, static_argnames=("interpret",))
def pallas_arbitrate(prio, seq, elig, *, interpret: bool = False):
    """Padded ``priority_arbiter`` call: returns ``(best_prio, best_idx)``
    per row, ``best_prio == BIG`` (and ``best_idx == 0``) if the row has
    no eligible entry — exactly ``ref.priority_arbiter_ref``."""
    H = prio.shape[0]
    (pp, sp, ep), (bh, bc) = pad_tiles((prio, seq, elig),
                                       (BIG, BIG, False), col_pref=256)
    bp, bi = priority_arbiter(pp, sp, ep, block_h=bh, block_c=bc,
                              interpret=interpret)
    return bp[:H], bi[:H]


def _topk_normalize(vals, idx):
    """Raw kernel top-K -> caller convention: descending keys clamped at
    0, columns -1 where fewer than K positive keys exist."""
    return jnp.maximum(vals, 0), jnp.where(vals > 0, idx, -1)


@partial(jax.jit, static_argnames=("K", "interpret"))
def pallas_topk(keys, K: int, *, interpret: bool = False):
    """Padded ``srpt_topk`` call: returns ``(vals, idx)`` — the K largest
    keys per row (descending, clamped at 0) and their source columns
    (-1 where fewer than K positive keys exist)."""
    H = keys.shape[0]
    keys = pad_min_cols(keys, K)
    (kp,), (bh, bm) = pad_tiles((keys,), (NEG,), col_pref=512)
    vals, idx = srpt_topk(kp, K, block_h=bh, block_m=bm,
                          interpret=interpret)
    return _topk_normalize(vals[:H], idx[:H])


def fused_slot(down=None, up=None, topk=None, *,
               interpret: bool | None = None):
    """The ``pallas_fused`` backend's per-slot entry point: pad every
    present stage with the shared :func:`pad_tiles` policy and issue ONE
    ``fused.fused_slot`` kernel launch (DESIGN.md §11).

      down / up   ``(prio (H, cap), seq, elig)`` — downlink / TOR-uplink
                  drain problems (either may be ``None``)
      topk        ``(keys (H2, M), K)`` — the SRPT grant-set problem

    Returns a dict with a key per present stage: ``"down"``/``"up"`` ->
    ``(best_prio (H,), best_idx (H,))`` exactly as :func:`arbitrate`;
    ``"topk"`` -> normalized ``(vals (H2, K), idx (H2, K))`` exactly as
    :func:`topk`. Operands too large for whole-array VMEM blocks
    (``FUSED_VMEM_LIMIT_BYTES``) fall back to the staged per-stage
    kernels — bit-identical either way."""
    interpret = resolve_interpret(interpret)
    d_pad = u_pad = k_pad = None
    K = 0
    nbytes = 0
    if down is not None:
        d_pad, _ = pad_tiles(down, (BIG, BIG, False))
        nbytes += sum(4 * a.size for a in d_pad)
    if up is not None:
        u_pad, _ = pad_tiles(up, (BIG, BIG, False))
        nbytes += sum(4 * a.size for a in u_pad)
    if topk is not None:
        keys, K = topk
        keys = pad_min_cols(keys, K)
        (kp,), _ = pad_tiles((keys,), (NEG,))
        k_pad = kp
        nbytes += 4 * kp.size + 8 * kp.shape[0] * K
    if nbytes > FUSED_VMEM_LIMIT_BYTES:
        out = {}
        if down is not None:
            out["down"] = pallas_arbitrate(*down, interpret=interpret)
        if up is not None:
            out["up"] = pallas_arbitrate(*up, interpret=interpret)
        if topk is not None:
            out["topk"] = pallas_topk(topk[0], topk[1],
                                      interpret=interpret)
        return out
    raw = fused_mod.fused_slot(down=d_pad, up=u_pad, keys=k_pad, K=K,
                               interpret=interpret)
    raw = list(raw)
    out = {}
    if down is not None:
        H = down[0].shape[0]
        out["down"] = (raw[0][:H], raw[1][:H])
        raw = raw[2:]
    if up is not None:
        U = up[0].shape[0]
        out["up"] = (raw[0][:U], raw[1][:U])
        raw = raw[2:]
    if topk is not None:
        H2 = topk[0].shape[0]
        out["topk"] = _topk_normalize(raw[0][:H2], raw[1][:H2])
    return out


# -------------------------------------------------------- dispatchers ------

def arbitrate(prio, seq, elig, *, backend: str = "reference",
              interpret: bool | None = None):
    """Strict-priority, FIFO-within-level winner per row on the chosen
    backend. Returns ``(best_prio (H,), best_idx (H,))``; rows with no
    eligible entry return ``(BIG, 0)``. Bit-identical across backends.
    ``pallas_fused`` routes here for call sites outside the fused slot
    (they run the staged kernel)."""
    if resolve_backend(backend) == "reference":
        return priority_arbiter_ref(prio, seq, elig)
    return pallas_arbitrate(prio, seq, elig,
                            interpret=resolve_interpret(interpret))


def topk(keys, K: int, *, backend: str = "reference",
         interpret: bool | None = None):
    """Per-row top-K keys + source columns on the chosen backend.
    Returns ``(vals (H, K), idx (H, K))``: descending keys clamped at 0,
    columns -1 where fewer than K positive keys exist. Ties resolve to
    the lowest column on both backends (``lax.top_k`` stability)."""
    if resolve_backend(backend) == "reference":
        return srpt_topk_ref(keys, K)
    return pallas_topk(keys, K, interpret=resolve_interpret(interpret))


__all__ = ["BACKENDS", "resolve_backend", "resolve_interpret",
           "arbitrate", "topk", "fused_slot", "pad_tiles", "pad_min_cols",
           "pallas_arbitrate", "pallas_topk", "FUSED_VMEM_LIMIT_BYTES"]
