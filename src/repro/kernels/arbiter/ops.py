"""Standalone Pallas entry points for the arbitration kernels.

Thin compatibility layer over ``dispatch.py``, which owns the shared
padding/block-size heuristics (rows pad to the 8-sublane multiple,
columns to the 128-lane multiple — the old per-call ``bc = 256 if cap %
256 == 0 else cap`` degenerated to one un-tiled block for any
non-multiple capacity such as ``ring_cap=1000``) and the
reference/pallas backend selection the simulator uses
(``SimConfig.backend``, DESIGN.md §6).

``interpret=None`` auto-selects: interpreted everywhere except on a
real TPU (``dispatch.resolve_interpret``).
"""
from __future__ import annotations

from repro.kernels.arbiter.dispatch import (pallas_arbitrate, pallas_topk,
                                            resolve_interpret)
from repro.kernels.arbiter.kernel import BIG, NEG


def arbitrate(prio, seq, elig, *, interpret: bool | None = None):
    """Pallas strict-priority-then-FIFO winner per row; see
    :func:`dispatch.arbitrate` for the backend-dispatched form."""
    return pallas_arbitrate(prio, seq, elig,
                            interpret=resolve_interpret(interpret))


def topk(keys, K: int, *, interpret: bool | None = None):
    """Pallas per-row top-K ``(vals, idx)``; see :func:`dispatch.topk`
    for the backend-dispatched form."""
    return pallas_topk(keys, K, interpret=resolve_interpret(interpret))


__all__ = ["arbitrate", "topk", "BIG", "NEG"]
