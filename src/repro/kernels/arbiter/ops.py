"""Jit'd wrappers for the arbitration kernels (pad rows/cols to block
multiples; interpret mode for CPU validation)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.arbiter.kernel import priority_arbiter, srpt_topk, BIG


def _pad_rows(x, bh, fill):
    H = x.shape[0]
    p = (-H) % bh
    return jnp.pad(x, ((0, p),) + ((0, 0),) * (x.ndim - 1),
                   constant_values=fill), H


@partial(jax.jit, static_argnames=("interpret",))
def arbitrate(prio, seq, elig, *, interpret: bool = False):
    H, cap = prio.shape
    bh = 8 if H % 8 == 0 else (H if H <= 8 else 1)
    bc = 256 if cap % 256 == 0 else cap
    pp, H0 = _pad_rows(prio, bh, BIG)
    sp, _ = _pad_rows(seq, bh, BIG)
    ep, _ = _pad_rows(elig, bh, False)
    bp, bi = priority_arbiter(pp, sp, ep, block_h=bh, block_c=bc,
                              interpret=interpret)
    return bp[:H0], bi[:H0]


@partial(jax.jit, static_argnames=("K", "interpret"))
def topk(keys, K: int, *, interpret: bool = False):
    H, M = keys.shape
    if M < K:   # fewer candidates than K: pad columns with ineligible zeros
        keys = jnp.pad(keys, ((0, 0), (0, K - M)))
        M = K
    bh = 8 if H % 8 == 0 else (H if H <= 8 else 1)
    bm = 512 if M % 512 == 0 else M
    kp, H0 = _pad_rows(keys, bh, 0)
    out = srpt_topk(kp, K, block_h=bh, block_m=bm, interpret=interpret)
    return out[:H0]
