"""Flash attention Pallas TPU kernel: online-softmax, blockwise K/V streaming.

Grid: (B, H, num_q_blocks, num_kv_blocks) — the KV dimension is minor, so on
TPU the iterations for one (b, h, qi) run sequentially and the running
(m, l, acc) state lives in VMEM scratch across them. Q/K/V/O blocks are tiled
via BlockSpec into VMEM; MXU-aligned block sizes (multiples of 128) are
enforced by the ops.py wrapper.

Supports causal masking, sliding windows, and GQA (q-head -> kv-head mapping
in the K/V index_maps).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: int | None,
                 bq: int, bk: int, nk: int, kv_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    run = True
    if causal:
        run = (ki * bk) <= (qi * bq + bq - 1)   # block not fully above diag

    @pl.when(run if causal else True)
    def _body():
        q = q_ref[0, :, 0, :].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)          # (bk, dv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = k_pos < kv_len
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _fin():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: int | None = None, scale: float | None = None,
                    block_q: int = 128, block_kv: int = 128,
                    kv_len: int | None = None, interpret: bool = False):
    """q: (B, Sq, H, d); k/v: (B, Skv, KV, d/dv), Sq % block_q == 0,
    Skv % block_kv == 0, H % KV == 0. Returns (B, Sq, H, dv)."""
    B, Sq, H, d = q.shape
    _, Skv, KV, dv = v.shape
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    kv_len = Skv if kv_len is None else kv_len
    nq, nk = Sq // block_q, Skv // block_kv

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        bq=block_q, bk=block_kv, nk=nk, kv_len=kv_len)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, block_kv, 1, d),
                         lambda b, h, qi, ki, G=G: (b, ki, h // G, 0)),
            pl.BlockSpec((1, block_kv, 1, dv),
                         lambda b, h, qi, ki, G=G: (b, ki, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, dv),
                               lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),        # m
            pltpu.VMEM((block_q,), jnp.float32),        # l
            pltpu.VMEM((block_q, dv), jnp.float32),     # acc
        ],
        interpret=interpret,
    )(q, k, v)
