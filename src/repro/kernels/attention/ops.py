"""Jit'd public wrapper for the flash attention kernel: pads sequence dims to
block multiples, picks MXU-aligned blocks, exposes interpret mode for CPU."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.attention.kernel import flash_attention


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_kv",
                                   "interpret"))
def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              block_q: int = 128, block_kv: int = 128,
              interpret: bool = False):
    B, Sq, H, d = q.shape
    _, Skv, KV, dv = v.shape
    bq = min(block_q, max(8, Sq))
    bk = min(block_kv, max(8, Skv))
    pq = (-Sq) % bq
    pk = (-Skv) % bk
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else v
    out = flash_attention(qp, kp, vp, causal=causal, window=window,
                          block_q=bq, block_kv=bk, kv_len=Skv,
                          interpret=interpret)
    return out[:, :Sq]
