"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import math

import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int | None = None,
                  scale: float | None = None, kv_len: int | None = None):
    """Naive attention. q: (B,Sq,H,d); k/v: (B,Skv,KV,d|dv). f32 math."""
    B, Sq, H, d = q.shape
    _, Skv, KV, dv = v.shape
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    kv_len = Skv if kv_len is None else kv_len

    qf = q.astype(jnp.float32).reshape(B, Sq, KV, G, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqkgd,bjkd->bqkgj", qf, kf) * scale
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Skv)[None, :]
    mask = k_pos < kv_len
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window is not None:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    o = jnp.einsum("bqkgj,bjkd->bqkgd", p, vf)
    return o.reshape(B, Sq, H, dv).astype(q.dtype)
