"""Pure-jnp oracle for the SSD kernel: the sequential per-token recurrence.

    state_t = exp(dt_t * A) * state_{t-1} + dt_t * B_t (x) x_t
    y_t     = C_t . state_t
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def ssd_ref(x, dt, A, Bm, Cm):
    """x: (B,S,H,P); dt: (B,S,H); A: (H,); Bm/Cm: (B,S,N).
    Returns (y (B,S,H,P) f32, final_state (B,H,P,N) f32)."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)

    def step(state, inp):
        xt, dtt, bt, ct = inp                       # (B,H,P),(B,H),(B,N),(B,N)
        decay = jnp.exp(dtt * Af)                   # (B,H)
        upd = jnp.einsum("bn,bhp->bhpn", bt, xt * dtt[..., None])
        state = state * decay[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", state, ct)
        return state, y

    init = jnp.zeros((B, H, P, N), jnp.float32)
    final, ys = lax.scan(step, init,
                         (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
                          Bf.transpose(1, 0, 2), Cf.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2, 3), final
