"""Jit'd wrapper for the SSD kernel: pads the sequence to a chunk multiple."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ssd.kernel import ssd_pallas


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, A, Bm, Cm, *, chunk: int = 128, interpret: bool = False):
    B, S, H, P = x.shape
    c = min(chunk, S) if S % min(chunk, S) == 0 else chunk
    pad = (-S) % c
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    y, fs = ssd_pallas(x, dt, A, Bm, Cm, chunk=c, interpret=interpret)
    return y[:, :S], fs
