"""Mamba2 SSD (state-space duality) Pallas TPU kernel.

Chunked dual form: grid (B, H, num_chunks) with the chunk dimension minor —
iterations for one (b, h) run sequentially on TPU, so the running inter-chunk
state (P x N) lives in VMEM scratch. Per chunk:

    y = tril(CB^T * decay) @ (dt*x)  +  (C * decay_in) @ state
    state = decay_chunk * state + B^T @ (dt * decay_out * x)

Inputs follow repro.models.ssm.ssd_chunked layout: x (B,S,H,P), dt (B,S,H),
A (H,), Bm/Cm (B,S,N). Output y (B,S,H,P) f32 + final state (B,H,P,N).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, fs_ref, st_scr, *,
                chunk: int, nc: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        st_scr[...] = jnp.zeros_like(st_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # (L, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # (L,)
    a = a_ref[0]                                     # scalar A_h
    bm = b_ref[0].astype(jnp.float32)                # (L, N)
    cm = c_ref[0].astype(jnp.float32)                # (L, N)

    dA = dt * a                                      # (L,)
    cums = jnp.cumsum(dA)                            # (L,)

    # intra-chunk: att[i,j] = (C_i . B_j) * exp(cums_i - cums_j) * dt_j, i>=j
    diff = cums[:, None] - cums[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(ii >= jj, jnp.exp(diff), 0.0)
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    att = cb * decay * dt[None, :]
    y = jax.lax.dot_general(att, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk contribution from the carried state
    state = st_scr[...]                              # (P, N)
    y += jnp.exp(cums)[:, None] * jax.lax.dot_general(
        cm, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # state update: state' = exp(sum dA) * state + sum_j w_j * x_j B_j^T
    w = dt * jnp.exp(cums[-1] - cums)                # (L,)
    upd = jax.lax.dot_general(x * w[:, None], bm, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (P, N)
    st_scr[...] = jnp.exp(cums[-1]) * state + upd

    @pl.when(ci == nc - 1)
    def _fin():
        fs_ref[0, 0, :, :] = st_scr[...]


def ssd_pallas(x, dt, A, Bm, Cm, *, chunk: int = 128,
               interpret: bool = False):
    """x: (B,S,H,P); dt: (B,S,H); A: (H,); Bm/Cm: (B,S,N). S % chunk == 0.
    Returns (y (B,S,H,P) f32, final_state (B,H,P,N) f32)."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0
    nc = S // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk, nc=nc)
    y, fs = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, ci: (b, ci, h)),
            pl.BlockSpec((1,), lambda b, h, ci: (h,)),
            pl.BlockSpec((1, chunk, N), lambda b, h, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, ci: (b, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, ci: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A.astype(jnp.float32), Bm, Cm)
    return y, fs
