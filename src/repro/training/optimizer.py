"""AdamW (from scratch) with global-norm clipping and cosine schedule.

Optimizer state (m, v) is float32 and inherits each parameter's sharding, so
under FSDP+TP the states are fully distributed (ZeRO-ish by construction).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    min_lr_frac: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: Any = jnp.float32   # set bfloat16 to halve optimizer memory


def schedule(oc: OptConfig, step):
    step = step.astype(F32)
    warm = jnp.minimum(step / jnp.maximum(oc.warmup_steps, 1), 1.0)
    t = jnp.clip((step - oc.warmup_steps)
                 / jnp.maximum(oc.total_steps - oc.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = oc.min_lr_frac + (1 - oc.min_lr_frac) * cos
    return oc.lr * warm * frac


def init_opt_state(params, oc: OptConfig):
    zeros = lambda p: jnp.zeros(p.shape, oc.state_dtype)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def _decay_mask(path) -> bool:
    """Apply weight decay only to matrices (skip norms/biases/scalars)."""
    name = str(path[-1].key) if hasattr(path[-1], "key") else str(path[-1])
    return name not in ("w", "b", "bq", "bk", "bv", "b1", "b2", "dt_bias",
                        "A_log", "D_skip", "norm", "kv_norm")


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(x.astype(F32) ** 2)
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, opt_state, oc: OptConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = schedule(oc, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, oc.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1, b2 = oc.beta1, oc.beta2
    bc1 = 1 - b1 ** step.astype(F32)
    bc2 = 1 - b2 ** step.astype(F32)

    flat_p, treedef = jax.tree.flatten_with_path(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])

    new_p, new_m, new_v = [], [], []
    for (path, p), g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        g = g.astype(F32) * scale
        m2 = b1 * m.astype(F32) + (1 - b1) * g
        v2 = b2 * v.astype(F32) + (1 - b2) * g * g
        upd = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + oc.eps)
        if oc.weight_decay and _decay_mask(path):
            upd = upd + oc.weight_decay * p.astype(F32)
        new_p.append((p.astype(F32) - lr * upd).astype(p.dtype))
        new_m.append(m2.astype(oc.state_dtype))
        new_v.append(v2.astype(oc.state_dtype))

    tdef = jax.tree.structure(params)
    out_params = jax.tree.unflatten(tdef, new_p)
    new_state = {"m": jax.tree.unflatten(tdef, new_m),
                 "v": jax.tree.unflatten(tdef, new_v),
                 "step": step}
    return out_params, new_state, {"grad_norm": gnorm, "lr": lr}
