"""train_step / prefill_step / serve_step builders.

``build_train_step`` returns a function (params, opt_state, batch) ->
(params, opt_state, metrics) with microbatch gradient accumulation
(lax.scan), remat, and activation sharding constraints. All builders are
mesh-agnostic: pass a mesh to get sharding hints, or none for single-device
CPU tests.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model as M
from repro.training.optimizer import OptConfig, adamw_update
from repro.distrib import sharding as SH

F32 = jnp.float32


def choose_grad_accum(cfg: ModelConfig, shape: ShapeConfig,
                      sizes: dict[str, int]) -> int:
    """Microbatch count: keep per-device microbatch tokens bounded."""
    import math
    bax = SH.batch_axes(sizes, shape.global_batch)
    shards = math.prod(sizes[a] for a in bax) if bax else 1
    per_dev = shape.global_batch // shards
    target_tokens = 8192 if cfg.d_model >= 8192 else 16384
    per_seq = shape.seq_len
    want = max(1, (per_dev * per_seq) // target_tokens)
    # largest divisor of per_dev not exceeding want
    best = 1
    for a in range(1, per_dev + 1):
        if per_dev % a == 0 and a <= want:
            best = a
    return best


def build_train_step(cfg: ModelConfig, oc: OptConfig, *,
                     mesh: Mesh | None = None,
                     shape: ShapeConfig | None = None,
                     grad_accum: int | None = None,
                     remat: bool = True, unroll: bool = False,
                     accum_dtype=F32):
    shardings = None
    if mesh is not None and shape is not None:
        if grad_accum is None:
            grad_accum = choose_grad_accum(cfg, shape, SH.mesh_sizes(mesh))
        shardings = SH.activation_shardings(cfg, mesh, shape,
                                            grad_accum=grad_accum)
    grad_accum = grad_accum or 1

    def micro_loss(params, mb):
        return M.loss_fn(cfg, params, mb, shardings=shardings, remat=remat,
                         unroll=unroll)

    def train_step(params, opt_state, batch):
        if grad_accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                micro_loss, has_aux=True)(params, batch)
        else:
            def split(x):
                return x.reshape((grad_accum, x.shape[0] // grad_accum)
                                 + x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc_fn(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(micro_loss, has_aux=True)(
                    params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(accum_dtype), g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype),
                              params)
            (grads, loss_sum), _ = lax.scan(acc_fn, (g0, jnp.zeros((), F32)),
                                            micro,
                                            unroll=True if unroll else 1)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = loss_sum / grad_accum
            metrics = {}

        params, opt_state, opt_metrics = adamw_update(params, grads,
                                                      opt_state, oc)
        out = {"loss": loss, **opt_metrics}
        return params, opt_state, out

    return train_step


def build_prefill_step(cfg: ModelConfig, *, mesh: Mesh | None = None,
                       shape: ShapeConfig | None = None,
                       unroll: bool = False):
    shardings = None
    if mesh is not None and shape is not None:
        shardings = SH.activation_shardings(cfg, mesh, shape)

    def prefill_step(params, batch):
        logits, caches = M.forward_prefill(
            cfg, params, batch["tokens"],
            enc_embeds=batch.get("enc_embeds"),
            img_embeds=batch.get("img_embeds"),
            shardings=shardings, unroll=unroll)
        return logits, caches

    return prefill_step


def build_serve_step(cfg: ModelConfig, *, pos: int | None = None,
                     unroll: bool = False):
    """One-token decode. `pos` static (dry-run) or traced via the argument."""

    def serve_step(params, caches, token, position):
        p = pos if pos is not None else position
        logits, deltas = M.forward_decode(cfg, params, token, p, caches,
                                          unroll=unroll)
        return logits, deltas

    return serve_step
