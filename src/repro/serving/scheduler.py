"""Homa-SRPT serving scheduler: an inference server is a Homa receiver
(DESIGN.md §2.2) — many clients contend for its decode slots.

Mapping of the paper's mechanisms:

  blind/unscheduled (§2.2)   requests with a small remaining-token budget
                             (<= unsched_limit) skip the admission queue
  grants (§3.3)              admission of queued requests, issued in SRPT
                             order as slots free up
  dynamic priorities (§3.4)  priority classes from equal-work cutoffs over
                             the observed request-size distribution (Fig. 4's
                             algorithm, recomputed online — beyond-paper: the
                             paper's impl precomputes from workload knowledge)
  overcommitment (§3.5)      K extra requests are admitted beyond the decode
                             batch so a stalled/finished slot is refilled
                             without a scheduling round-trip
  SRPT run-to-completion     each step serves the batch_size best
                             (priority, remaining) requests
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from typing import Callable

import numpy as np

from repro.core.priorities import equal_bytes_cutoffs


@dataclasses.dataclass
class Request:
    rid: int
    prompt_len: int
    max_new_tokens: int
    arrival: float
    generated: int = 0
    done: bool = False
    first_token_time: float | None = None
    finish_time: float | None = None

    @property
    def remaining(self) -> int:
        return self.max_new_tokens - self.generated


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    batch_size: int = 8           # decode slots (the "downlink")
    overcommit: int = 7           # K extra admitted (paper: #sched prios)
    n_prios: int = 8
    unsched_limit: int = 32       # remaining <= this skips the queue
    history: int = 512            # sliding window for cutoff estimation
    srpt: bool = True             # False -> FIFO (the "Basic" ablation)


class HomaScheduler:
    def __init__(self, cfg: SchedulerConfig):
        self.cfg = cfg
        self.queue: deque[Request] = deque()     # awaiting admission
        self.active: list[Request] = []          # admitted ("granted")
        self.finished: list[Request] = []
        self.size_history: deque[int] = deque(maxlen=cfg.history)
        self.cutoffs: list[int] = []

    # ------------------------------------------------------------ intake ---
    def submit(self, req: Request):
        self.size_history.append(req.max_new_tokens)
        self._refresh_cutoffs()
        if req.remaining <= self.cfg.unsched_limit:
            self.active.append(req)              # unscheduled fast path
        else:
            self.queue.append(req)
        self._admit()

    def _refresh_cutoffs(self):
        if len(self.size_history) >= 8:
            sizes = np.asarray(self.size_history)
            self.cutoffs = equal_bytes_cutoffs(
                sizes, sizes.astype(np.float64), self.cfg.n_prios)

    def priority(self, req: Request) -> int:
        """Higher value = served later (0 is best), from dynamic cutoffs."""
        if not self.cutoffs:
            return 0
        return int(np.searchsorted(self.cutoffs, req.remaining))

    def _admit(self):
        """Grant admission up to batch_size + overcommit active requests,
        SRPT order (the paper's top-K grant set)."""
        limit = self.cfg.batch_size + self.cfg.overcommit
        if self.cfg.srpt:
            q = sorted(self.queue, key=lambda r: (r.remaining, r.arrival))
        else:
            q = sorted(self.queue, key=lambda r: r.arrival)
        while len(self.active) < limit and q:
            r = q.pop(0)
            self.queue.remove(r)
            self.active.append(r)

    # ------------------------------------------------------------- serve ---
    def select_batch(self) -> list[Request]:
        """The batch_size best (priority, remaining) active requests."""
        live = [r for r in self.active if not r.done]
        key = (lambda r: (self.priority(r), r.remaining, r.arrival)) \
            if self.cfg.srpt else (lambda r: r.arrival)
        live.sort(key=key)
        return live[: self.cfg.batch_size]

    def step(self, decode_fn: Callable[[list[Request]], list[bool]],
             now: float) -> list[Request]:
        """One decode step: serve the selected batch, retire finished
        requests, refill from the admission queue. Returns retirees."""
        batch = self.select_batch()
        if not batch:
            self._admit()
            return []
        done_flags = decode_fn(batch)
        retired = []
        for r, d in zip(batch, done_flags):
            r.generated += 1
            if r.first_token_time is None:
                r.first_token_time = now
            if d or r.remaining <= 0:
                r.done = True
                r.finish_time = now
                retired.append(r)
        self.active = [r for r in self.active if not r.done]
        self.finished.extend(retired)
        self._admit()
        return retired

    # ------------------------------------------------------------- stats ---
    def slowdowns(self) -> np.ndarray:
        out = []
        for r in self.finished:
            ideal = max(r.max_new_tokens, 1)
            out.append((r.finish_time - r.arrival) / ideal)
        return np.asarray(out)
