"""Host-model benchmarks (DESIGN.md §10).

The paper's §5.3 compares the simulator against the real implementation
and attributes most of the residual latency gap to *host* effects —
per-packet software cost, batching, and NIC queueing — not the fabric.
These harnesses reproduce that gap with ``repro.core.hostmodel``:

  ``fig_hostmodel``    W1-W5 x host preset (ideal / kernel_bypass /
                       kernel_stack) under homa. The acceptance claim:
                       every workload shows a nonzero slowdown gap vs
                       the ideal host, monotone in per-packet cost
                       (stack > bypass > ideal), i.e. the "simulation
                       vs implementation" gap is a host artifact the
                       model recreates knob-by-knob.
  ``hostmodel_smoke``  one pinned W2 point (CI cell) run at ideal and
                       kernel_stack; slowdowns, completion and the
                       host busy/backlog stats are pinned exactly by
                       the committed baseline on both backends.

Points go through the cached ``sim_sweep`` path using WorkloadSpec
``spec`` points (size-capped so the CPU-budget horizon stays bounded).
"""
from __future__ import annotations

from benchmarks.common import sim_sweep, emit

PRESETS = ["ideal", "kernel_bypass", "kernel_stack"]
WORKLOADS = ["W1", "W2", "W3", "W4", "W5"]

# kernel_stack's effective TX rate is ~0.5 chunks/slot/host (1 slot base
# cost + amortized batch flush), so offered load must sit below that for
# every preset to reach steady state: 0.4 of line rate.
TOPO = dict(n_hosts=8, ring_cap=2048, max_slots=40_000)
LOAD = 0.4
N_MESSAGES = 500
MAX_BYTES = 65_536


def _spec(workload: str, n_messages: int) -> dict:
    return dict(kind="poisson", workload=workload, load=LOAD,
                n_messages=n_messages, max_bytes=MAX_BYTES)


def _row(workload: str, preset: str, r: dict) -> dict:
    h = r["host"] or {}
    return dict(
        workload=workload, host=preset,
        p50_all=round(r["p50_all"], 3),
        p99_small=round(r["p99_small"] or 0, 2),
        completion=round(r["completion_rate"], 3),
        tx_busy=round(h.get("tx_busy_frac") or 0, 3),
        tx_defer=round(h.get("tx_defer_frac") or 0, 3),
        rx_stall=round(h.get("rx_stall_frac") or 0, 3),
        rx_q_max=h.get("rx_q_max_chunks") or 0)


def fig_hostmodel(full: bool = False):
    """The §5.3 simulation-vs-implementation latency gap, W1-W5."""
    n_messages = 2000 if full else N_MESSAGES
    rows = []
    for preset in PRESETS:
        pts = [dict(spec=_spec(w, n_messages)) for w in WORKLOADS]
        res = sim_sweep(pts, protocol="homa", host=preset, **TOPO)
        for w, r in zip(WORKLOADS, res):
            rows.append(_row(w, preset, r))
    for r in rows:
        base = next(b for b in rows
                    if b["workload"] == r["workload"]
                    and b["host"] == "ideal")
        r["gap_p50"] = round(r["p50_all"] / base["p50_all"], 3)
    emit("fig_hostmodel", rows)
    # acceptance shape: the host gap is nonzero and monotone in
    # per-packet cost for every workload, and nothing is starved
    by = {(r["workload"], r["host"]): r for r in rows}
    for w in WORKLOADS:
        ideal = by[(w, "ideal")]
        bypass = by[(w, "kernel_bypass")]
        stack = by[(w, "kernel_stack")]
        assert ideal["gap_p50"] == 1.0, ideal
        assert bypass["gap_p50"] >= 1.0, (w, bypass)
        assert stack["gap_p50"] > bypass["gap_p50"], (w, bypass, stack)
        assert stack["gap_p50"] > 1.05, (w, stack)
        assert stack["completion"] == 1.0, (w, stack)
    return rows


def hostmodel_smoke(full: bool = False):
    """One pinned host-model point end-to-end (the CI cell): homa on a
    size-capped W2 at load 0.5, ideal vs kernel_stack. The kernel-stack
    leg must complete everything while showing a >5% p50 gap; exact
    numbers are pinned by the committed baseline on both backends."""
    pts = [dict(spec=dict(kind="poisson", workload="W2", load=0.5,
                          n_messages=400, max_bytes=MAX_BYTES))]
    rows = []
    for preset in ("ideal", "kernel_stack"):
        res = sim_sweep(pts, protocol="homa", host=preset, n_hosts=8,
                        ring_cap=2048, max_slots=25_000)
        rows.append(_row("W2", preset, res[0]))
    rows[1]["gap_p50"] = round(rows[1]["p50_all"] / rows[0]["p50_all"], 3)
    rows[0]["gap_p50"] = 1.0
    emit("hostmodel_smoke", rows)
    assert rows[0]["completion"] == 1.0 and rows[1]["completion"] == 1.0, \
        rows
    assert rows[1]["gap_p50"] > 1.05, rows
    return rows
