"""Fault-injection benchmarks (DESIGN.md §7).

Two harnesses, both through the cached ``sim_sweep`` path:

  ``fig_faults``    resilience figure: homa vs basic p99 small-message
                    slowdown + recovery time (a) across uplink loss
                    rates under ECMP, and (b) through a single-TOR-
                    uplink failure window under ECMP vs flowlet vs
                    adaptive routing. The acceptance claim lives here:
                    homa degrades gracefully and stays below basic, and
                    adaptive routing erases the failure window that
                    static ECMP turns into a black hole.
  ``faults_smoke``  one lossy leaf-spine point (CI cell): homa at 1%
                    uplink loss completes every message; the exact
                    retransmission/recovery numbers are pinned by the
                    committed baseline.

Scale matches ``fabric_figs`` CPU-budget defaults (16 hosts / 4 racks
at 2:1 oversubscription).
"""
from __future__ import annotations

from benchmarks.common import sim_sweep, emit

LOSS_RATES = [0.0, 0.005, 0.01, 0.02, 0.05]
ROUTINGS = ["ecmp", "flowlet", "adaptive"]
TOPO = dict(n_hosts=16, racks=4, oversub=2.0, n_messages=1200,
            ring_cap=1024, up_cap=2048, max_slots=30_000)
FAIL_WINDOW = (0, 2000, 6000)       # one uplink dark for 4000 slots


def _rows(proto, scenario, routing, up_loss, r):
    fl = r["faults"] or {}
    return dict(
        protocol=proto, scenario=scenario, routing=routing,
        up_loss=up_loss,
        p99_small=round(r["p99_small"] or 0, 2),
        p50_small=round(r["p50_small"] or 0, 2),
        completion=round(r["completion_rate"], 3),
        fault_lost=fl.get("fault_lost_chunks", 0),
        retx_chunks=fl.get("retx_chunks", 0),
        recovery_mean=round(fl["recovery_mean_slots"], 1)
        if fl.get("recovery_mean_slots") is not None else "",
        recovery_p99=round(fl["recovery_p99_slots"], 1)
        if fl.get("recovery_p99_slots") is not None else "")


def fig_faults(full: bool = False):
    """Homa vs basic under loss and failure (the resilience figure)."""
    t = dict(TOPO)
    rows = []
    for proto in ("homa", "basic"):
        # (a) Bernoulli uplink loss sweep, static ECMP routing
        pts = [dict(workload="W2", load=0.6)]
        for lr in LOSS_RATES:
            fab = dict(racks=t["racks"], oversub=t["oversub"],
                       up_cap=t["up_cap"])
            if lr > 0:
                fab["faults"] = dict(up_loss=lr)
            res = sim_sweep(pts, protocol=proto, fabric=fab,
                            n_hosts=t["n_hosts"],
                            n_messages=t["n_messages"],
                            ring_cap=t["ring_cap"],
                            max_slots=t["max_slots"])
            rows.append(_rows(proto, "loss", "ecmp", lr, res[0]))
        # (b) single-uplink failure window, routing-policy comparison
        for routing in ROUTINGS:
            fab = dict(racks=t["racks"], oversub=t["oversub"],
                       up_cap=t["up_cap"], routing=routing,
                       faults=dict(link_fail=[list(FAIL_WINDOW)]))
            res = sim_sweep(pts, protocol=proto, fabric=fab,
                            n_hosts=t["n_hosts"],
                            n_messages=t["n_messages"],
                            ring_cap=t["ring_cap"],
                            max_slots=t["max_slots"])
            rows.append(_rows(proto, "linkfail", routing, 0.0, res[0]))
    emit("fig_faults", rows)
    # acceptance shape: homa completes everything at every loss rate,
    # degrades monotonically-ish, and stays below basic's p99
    by = {(r["protocol"], r["scenario"], r["routing"], r["up_loss"]): r
          for r in rows}
    for lr in LOSS_RATES:
        h, b = by[("homa", "loss", "ecmp", lr)], \
            by[("basic", "loss", "ecmp", lr)]
        assert h["completion"] == 1.0, (lr, h)
        assert h["p99_small"] <= b["p99_small"], (lr, h, b)
    return rows


def faults_smoke(full: bool = False):
    """One lossy leaf-spine point end-to-end (the CI cell): homa at 1%
    uplink loss on W2 at 2:1 oversubscription still completes every
    message, with retransmission and recovery stats pinned exactly."""
    pts = [dict(workload="W2", load=0.5)]
    fab = dict(racks=4, oversub=2.0, faults=dict(up_loss=0.01))
    res = sim_sweep(pts, protocol="homa", fabric=fab, n_hosts=16,
                    n_messages=600, ring_cap=512, max_slots=20_000)
    r = res[0]
    fl = r["faults"]
    rows = [dict(protocol="homa", completion=r["completion_rate"],
                 lost_chunks=r["lost_chunks"],
                 fault_lost=fl["fault_lost_chunks"],
                 retx_chunks=fl["retx_chunks"],
                 msgs_lossy=fl["msgs_lossy"],
                 recovery_mean=round(fl["recovery_mean_slots"], 1),
                 recovery_p99=round(fl["recovery_p99_slots"], 1))]
    emit("faults_smoke", rows)
    assert r["completion_rate"] == 1.0, rows
    return rows
