"""Leaf-spine fabric benchmarks (paper §5.2 topology; DESIGN.md §5).

Three harnesses, all through the cached ``sim_sweep`` path:

  ``fabric_oversub``      p99 slowdown + TOR-uplink queue stats across
                          oversubscription ratios × protocols on a
                          Poisson workload (the regime where congestion
                          moves from receiver downlinks to TOR uplinks).
  ``fig14_fabric_incast`` the paper's Fig. 14 incast shape: fan-in
                          bursts into one receiver behind a 2:1
                          oversubscribed fabric, homa vs basic, swept
                          over the fan-in degree.
  ``fabric_smoke``        one small leaf-spine incast point (CI cell).

Default scale is CPU-budget (16 hosts / 4 racks); ``--full`` runs the
paper's 144-host / 9-rack topology.
"""
from __future__ import annotations

from benchmarks.common import sim_sweep, emit

OVERSUBS = [1.0, 2.0, 4.0]
FAB_PROTOS = ["homa", "basic", "pfabric"]


def _topo(full: bool) -> dict:
    if full:
        return dict(n_hosts=144, racks=9, n_messages=6000,
                    ring_cap=4096, up_cap=4096, max_slots=120_000)
    return dict(n_hosts=16, racks=4, n_messages=1200,
                ring_cap=1024, up_cap=2048, max_slots=30_000)


def fabric_oversub(full: bool = False):
    """Oversubscription × protocol sweep: with 4 racks, ~3/4 of Poisson
    traffic crosses the core, so tightening the uplink ratio shifts
    queueing into the TORs; Homa's wire priorities protect small
    messages there exactly as at the downlink."""
    t = _topo(full)
    loads = [0.5, 0.7] if full else [0.6]
    rows = []
    for proto in FAB_PROTOS:
        for ovs in OVERSUBS:
            fab = dict(racks=t["racks"], oversub=ovs, up_cap=t["up_cap"])
            pts = [dict(workload="W2", load=ld) for ld in loads]
            res = sim_sweep(pts, protocol=proto, fabric=fab,
                            n_hosts=t["n_hosts"],
                            n_messages=t["n_messages"],
                            ring_cap=t["ring_cap"],
                            max_slots=t["max_slots"])
            for pt, r in zip(pts, res):
                f = r["fabric"]
                rows.append(dict(
                    protocol=proto, oversub=ovs, load=pt["load"],
                    p99_small=round(r["p99_small"] or 0, 2),
                    p99_all=round(r["p99_all"] or 0, 2),
                    completion=round(r["completion_rate"], 3),
                    up_busy_frac=round(f["up_busy_frac"], 4),
                    up_q_mean_kb=round(f["up_q_mean_bytes"] / 1024, 1),
                    up_q_max_kb=round(f["up_q_max_bytes"] / 1024, 1),
                    lost_chunks=r["lost_chunks"]))
    emit("fabric_oversub", rows)
    return rows


def fig14_fabric_incast(full: bool = False):
    """Fig. 14 shape: repeated fan-in bursts + Poisson background on a
    2:1-oversubscribed leaf-spine, homa vs basic over the fan-in degree.
    The acceptance claim lives here: homa's p99 small-message slowdown
    stays low while basic's blows up with the burst size."""
    t = _topo(full)
    fan_ins = [4, 8, 12, 24, 48] if full else [4, 8, 12]
    burst = 2048
    rows = []
    for proto in ("homa", "basic"):
        pts = [dict(scenario=dict(
                    kind="incast", fan_in=f, burst_bytes=burst,
                    n_bursts=8, period_slots=1500, background="W2",
                    background_load=0.5,
                    n_background=t["n_messages"] // 2),
                    seed=2)
               for f in fan_ins]
        fab = dict(racks=t["racks"], oversub=2.0, up_cap=t["up_cap"])
        res = sim_sweep(pts, protocol=proto, fabric=fab,
                        n_hosts=t["n_hosts"], ring_cap=t["ring_cap"],
                        max_slots=t["max_slots"])
        for f, r in zip(fan_ins, res):
            fb = r["fabric"]
            rows.append(dict(
                protocol=proto, fan_in=f, burst_bytes=burst,
                p99_small=round(r["p99_small"] or 0, 2),
                p50_small=round(r["p50_small"] or 0, 2),
                completion=round(r["completion_rate"], 3),
                q_max_kb=round(r["q_max_bytes"] / 1024, 1),
                up_q_max_kb=round(fb["up_q_max_bytes"] / 1024, 1),
                lost_chunks=r["lost_chunks"]))
    emit("fig14_fabric_incast", rows)
    return rows


def fabric_smoke(full: bool = False):
    """One small leaf-spine incast run end-to-end (the CI cell): checks
    the fabric tier composes with the cached sweep path and that homa
    still completes everything without loss."""
    pts = [dict(scenario=dict(kind="incast", fan_in=8, burst_bytes=2048,
                              n_bursts=3, period_slots=1000,
                              background="W1", background_load=0.4,
                              n_background=200))]
    res = sim_sweep(pts, protocol="homa",
                    fabric=dict(racks=4, oversub=2.0), n_hosts=16,
                    ring_cap=512, max_slots=8000)
    r = res[0]
    rows = [dict(protocol="homa", completion=r["completion_rate"],
                 lost_chunks=r["lost_chunks"],
                 up_busy_frac=round(r["fabric"]["up_busy_frac"], 4))]
    emit("fabric_smoke", rows)
    assert r["completion_rate"] == 1.0 and r["lost_chunks"] == 0, rows
    return rows
