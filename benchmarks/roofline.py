"""Roofline analysis over the dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds per step (per device):

    compute    = FLOPs_dev / 197e12        (bf16 peak per v5e chip)
    memory     = HBM_bytes_dev / 819e9
    collective = collective_bytes_dev / 50e9   (per-chip ICI link bw)

Sources:
- FLOPs/bytes/collectives come from *measured* compiled artifacts. XLA's
  cost analysis counts a while-loop body once, so the measurement artifacts
  are compiled with unrolled scans (``--unroll``); for deep models we
  compile depth-reduced variants (``--nblocks 1|2``) and extrapolate
  affinely (cost(nb) = head + body*nb — exact, since every scan block is
  identical). Memory footprint comes from the default (scan) artifact,
  whose buffer allocation matches production.
- MODEL_FLOPS = 6*N*D (train) / 2*N_active*D (decode/prefill fwd), reported
  as the useful-compute ratio against measured HLO FLOPs.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"
PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def _load(name):
    p = ART / name
    if p.exists():
        d = json.loads(p.read_text())
        return d if d.get("status") == "ok" else None
    return None


def measured_totals(arch: str, shape: str, mesh: str):
    """(flops, bytes_accessed, collective_bytes) per device, from unrolled
    artifacts — direct or affine-extrapolated from nb=1,2."""
    full = _load(f"{arch}__{shape}__{mesh}__unrolled.json")
    if full:
        return (full["cost"].get("flops"),
                full["cost"].get("bytes accessed"),
                full["collectives"]["total_bytes"], "unrolled")
    nb1 = _load(f"{arch}__{shape}__{mesh}__unrolled__nb1.json")
    nb2 = _load(f"{arch}__{shape}__{mesh}__unrolled__nb2.json")
    if nb1 and nb2:
        nb_full = nb1["n_scan_blocks_full"]

        def extra(key, sub=None):
            a = (nb1["cost"][key] if sub is None
                 else nb1[sub]["total_bytes"])
            b = (nb2["cost"][key] if sub is None
                 else nb2[sub]["total_bytes"])
            body = b - a
            head = a - body
            return head + body * nb_full
        vals = (extra("flops"), extra("bytes accessed"),
                extra(None, "collectives"))
        # affine extrapolation requires cost(nb2) >= cost(nb1); XLA may
        # special-case single-iteration graphs — fall back when violated
        if all(v is not None and v > 0 for v in vals):
            return (*vals, "extrapolated(nb1,nb2)")
    return None, None, None, "missing"


def model_flops_per_device(d: dict) -> float:
    n = d["n_active_params"]
    toks = d["tokens_per_step"]
    mult = 6 if d["kind"] == "train" else 2
    return mult * n * toks / d["n_chips"]


def analyze_cell(arch: str, shape: str, mesh: str):
    base = _load(f"{arch}__{shape}__{mesh}.json")
    if base is None:
        return None
    flops, habytes, coll, src = measured_totals(arch, shape, mesh)
    if flops is None:
        # fall back: analytic flops, scan-artifact bytes (lower bounds)
        flops = model_flops_per_device(base)
        habytes = base["cost"].get("bytes accessed", 0)
        coll = base["collectives"]["total_bytes"]
        src = "analytic-fallback"
    t_comp = flops / PEAK_FLOPS
    t_mem = habytes / HBM_BW
    t_coll = coll / LINK_BW
    dominant = max((t_comp, "compute"), (t_mem, "memory"),
                   (t_coll, "collective"))[1]
    mf = model_flops_per_device(base)
    mem = base.get("memory", {})
    hbm_resident = (mem.get("argument_size_in_bytes", 0)
                    + mem.get("temp_size_in_bytes", 0))
    return {
        "arch": arch, "shape": shape, "mesh": mesh, "source": src,
        "t_compute_s": t_comp, "t_memory_s": t_mem,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops_dev": mf, "hlo_flops_dev": flops,
        "useful_ratio": mf / flops if flops else None,
        "roofline_frac": t_comp / max(t_comp, t_mem, t_coll)
        if max(t_comp, t_mem, t_coll) else None,
        "hbm_resident_gb": hbm_resident / 1e9,
        "fits_hbm16": hbm_resident <= 16e9,
        "compile_s": base.get("compile_s"),
        "notes": ";".join(base.get("sharding_notes", [])),
    }


def backend_compare(full: bool = False):
    """Simulator-side roofline cell: slots/sec of the per-slot
    arbitration hot path under each compute backend — reference vs
    pallas-interpret vs pallas_fused-interpret everywhere, plus the
    compiled pallas/pallas_fused rows when a TPU is attached (interpret
    mode emulates the kernels in plain XLA, so only the compiled rows
    measure real kernel dispatch; DESIGN.md §6/§11 — the fused row is
    where the one-launch-per-slot win shows). Registered as the
    ``backend_compare`` harness in benchmarks/run.py and runnable
    standalone via ``--backend-cell``."""
    import time

    import jax

    from benchmarks.common import emit
    from repro.core import SimConfig, simulate, make_messages

    n_msgs, max_slots = (2000, 30_000) if full else (600, 8_000)
    tbl = make_messages("W2", n_hosts=16, load=0.7, n_messages=n_msgs,
                        slot_bytes=256, seed=0)
    cells = [("reference", dict(backend="reference")),
             ("pallas-interpret", dict(backend="pallas",
                                       pallas_interpret=True)),
             ("pallas_fused-interpret", dict(backend="pallas_fused",
                                             pallas_interpret=True))]
    if jax.default_backend() == "tpu":
        cells.append(("pallas-compiled", dict(backend="pallas",
                                              pallas_interpret=False)))
        cells.append(("pallas_fused-compiled",
                      dict(backend="pallas_fused",
                           pallas_interpret=False)))
    rows = []
    for label, kw in cells:
        cfg = SimConfig(protocol="homa", n_hosts=16, ring_cap=1024,
                        max_slots=max_slots, **kw)
        t0 = time.perf_counter()
        simulate(cfg, tbl)                          # compile + warm caches
        warm = time.perf_counter() - t0
        t0 = time.perf_counter()
        r = simulate(cfg, tbl)
        dt = time.perf_counter() - t0
        # cold-call minus steady-state wall ~ trace+compile time of the
        # production program (the traced program's exact AOT split is
        # reported by the trace_smoke cell; DESIGN.md §8)
        rows.append(dict(backend=label, jax_backend=jax.default_backend(),
                         slots=max_slots, wall_s=round(dt, 3),
                         warm_s=round(warm, 3),
                         compile_est_s=round(max(warm - dt, 0.0), 3),
                         slots_per_sec=round(max_slots / dt),
                         n_complete=r.n_complete))
    # the backends must agree on the physics, whatever their speed
    # (a real error, not an assert: must survive `python -O`)
    if len({row["n_complete"] for row in rows}) != 1:
        raise RuntimeError(f"backend divergence in n_complete: {rows}")
    emit("backend_compare", rows)
    return rows


def fused_speed(full: bool = False):
    """Staged-vs-fused micro cell, pinned by ``check_regression.py``:
    one fabric-enabled homa run (all three fused stages live — downlink
    drain, TOR uplink drain, SRPT grant top-K) on the staged pallas and
    fused pallas_fused backends. Deterministic fields (completion count
    and checksum, bit-match flag) gate EXACTLY; wall fields gate within
    a generous ratio. Interpret mode on CPU measures trace/launch
    overhead only — the HBM-round-trip win needs the compiled-TPU rows
    of ``backend_compare``."""
    import time

    import numpy as np

    import jax

    from benchmarks.common import emit
    from repro.core import SimConfig, FabricConfig, simulate, \
        make_messages

    n_msgs, max_slots = (1200, 12_000) if full else (300, 3_000)
    tbl = make_messages("W2", n_hosts=16, load=0.7, n_messages=n_msgs,
                        slot_bytes=256, seed=0)
    fab = FabricConfig(racks=4, oversub=2.0, up_cap=256)
    interpret = jax.default_backend() != "tpu"
    results, walls = {}, {}
    for backend in ("pallas", "pallas_fused"):
        cfg = SimConfig(protocol="homa", n_hosts=16, ring_cap=512,
                        max_slots=max_slots, fabric=fab, backend=backend,
                        pallas_interpret=interpret)
        simulate(cfg, tbl)                          # compile + warm caches
        t0 = time.perf_counter()
        results[backend] = simulate(cfg, tbl)
        walls[backend] = time.perf_counter() - t0
    bitmatch = bool(np.array_equal(results["pallas"].completion,
                                   results["pallas_fused"].completion))
    rows = [dict(
        mode="interpret" if interpret else "compiled",
        slots=max_slots,
        n_complete=results["pallas_fused"].n_complete,
        completion_sum=int(np.asarray(
            results["pallas_fused"].completion, np.int64).sum()),
        bitmatch=bitmatch,
        staged_s=round(walls["pallas"], 3),
        fused_s=round(walls["pallas_fused"], 3),
        speedup=round(walls["pallas"] / walls["pallas_fused"], 3),
    )]
    if not bitmatch:
        # a real error, not an assert: must survive `python -O`
        raise RuntimeError(f"fused backend diverges from staged: {rows}")
    emit("fused_speed", rows)
    return rows


def main():
    if "--backend-cell" in sys.argv[1:]:
        backend_compare("--full" in sys.argv[1:])
        return
    from repro.configs import ARCH_NAMES
    from repro.configs.base import SHAPES, cell_is_skipped
    rows = []
    meshes = sys.argv[1:] or ["16x16"]
    for arch in ARCH_NAMES:
        for shape in SHAPES:
            if cell_is_skipped(arch, shape):
                continue
            for mesh in meshes:
                r = analyze_cell(arch, shape, mesh)
                if r:
                    rows.append(r)
    cols = ["arch", "shape", "mesh", "t_compute_s", "t_memory_s",
            "t_collective_s", "dominant", "roofline_frac", "useful_ratio",
            "hbm_resident_gb", "fits_hbm16", "source"]
    print(",".join(cols))
    for r in rows:
        print(",".join(
            f"{r[c]:.4g}" if isinstance(r[c], float) else str(r[c])
            for c in cols))
    out = ART.parent / "roofline.json"
    out.write_text(json.dumps(rows, indent=1))
    print(f"# wrote {out} ({len(rows)} cells)", file=sys.stderr)


if __name__ == "__main__":
    main()
