"""One benchmark per paper table/figure (scaled; see common.py).

Each fig*(full) function returns CSV rows; benchmarks/run.py orchestrates.
Points that share their compile-time config (protocol, topology,
overcommit, slot size) are grouped through ``sim_sweep`` so each group
costs one jit trace; only figures that vary compile-time parameters per
point (fig14: slot size, fig19: overcommit) still loop over ``sim_run``.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import sim_run, sim_sweep, emit

# all six registered protocols, ndp included (it used to be implemented in
# the simulator but omitted from every sweep)
PROTOS = ["homa", "basic", "phost", "pias", "pfabric", "ndp"]
LOADS_FIG12 = [0.8, 0.5]


def _fig12_points(proto: str, workload: str, full: bool) -> list[dict]:
    """The (load-swept) points one fig12/fig13 cell shares — one sim_sweep
    group per (workload, protocol), so fig13 reuses fig12's cache."""
    loads = LOADS_FIG12 if full else [0.8]
    # NDP/pHost can't sustain 80% (paper): cap like the paper did
    return [dict(workload=workload,
                 load=(0.7 if proto in ("phost", "ndp") and ld > 0.7
                       else ld))
            for ld in loads]


def fig12_slowdown(full: bool = False):
    """99p slowdown vs message size per (protocol, workload, load)."""
    workloads = ["W1", "W2", "W3", "W4", "W5"] if full else ["W2", "W4"]
    protos = PROTOS if full else ["homa", "basic", "phost", "pfabric"]
    rows = []
    for w in workloads:
        for proto in protos:
            pts = _fig12_points(proto, w, full)
            for pt, r in zip(pts, sim_sweep(pts, protocol=proto)):
                for sz, p99, p50 in zip(r["p99_by_size"]["sizes"],
                                        r["p99_by_size"]["p"],
                                        r["p99_by_size"]["median"]):
                    rows.append(dict(workload=w, protocol=proto,
                                     load=pt["load"],
                                     size_bytes=round(sz),
                                     p99_slowdown=round(p99, 2),
                                     p50_slowdown=round(p50, 2)))
    emit("fig12_slowdown", rows)
    return rows


def fig13_median(full: bool = False):
    """Median slowdown (same runs as fig12 — cached)."""
    workloads = ["W1", "W2", "W3", "W4", "W5"] if full else ["W2", "W4"]
    protos = PROTOS if full else ["homa", "basic", "phost", "pfabric"]
    rows = []
    for w in workloads:
        for proto in protos:
            r = sim_sweep(_fig12_points(proto, w, full), protocol=proto)[0]
            rows.append(dict(workload=w, protocol=proto,
                             p50_small=r["p50_small"],
                             p50_all=r["p50_all"]))
    emit("fig13_median", rows)
    return rows


def fig15_utilization(full: bool = False):
    """Highest sustainable load per (protocol, workload): ascending-load
    sweep; sustainable = >=95% of messages complete within the window and
    nothing is lost. Valid when the arrival horizon + drain fits max_slots,
    which holds for W1-W3 at default scale (W4/W5's multi-MB messages need
    windows ~10x longer — full mode only; see EXPERIMENTS notes)."""
    workloads = ["W1", "W2", "W3", "W4", "W5"] if full else ["W3"]
    loads = ([0.55, 0.65, 0.75, 0.85, 0.92] if full
             else [0.7, 0.8, 0.9])
    rows = []
    for w in workloads:
        for proto in PROTOS:
            pts = [dict(workload=w, load=ld) for ld in loads]
            best = 0.0
            for pt, r in zip(pts, sim_sweep(pts, protocol=proto)):
                if r["completion_rate"] >= 0.95 and r["lost_chunks"] == 0:
                    best = pt["load"]
            rows.append(dict(workload=w, protocol=proto,
                             max_sustainable_load=best))
    emit("fig15_utilization", rows)
    return rows


def fig16_wasted_bandwidth(full: bool = False):
    """Wasted (idle-but-withheld) downlink fraction vs load, by
    overcommitment level. Paper: W4."""
    loads = [0.5, 0.6, 0.7, 0.8, 0.9] if full else [0.6, 0.8, 0.9]
    rows = []
    for k in ([1, 2, 4, 7] if full else [1, 7]):
        pts = [dict(workload="W4", load=ld) for ld in loads]
        for pt, r in zip(pts, sim_sweep(pts, protocol="homa", overcommit=k,
                                        n_messages=1500)):
            rows.append(dict(overcommit=k, load=pt["load"],
                             wasted_frac=round(r["wasted_frac"], 4),
                             busy_frac=round(r["busy_frac"], 4),
                             completion=round(r["completion_rate"], 3)))
    emit("fig16_wasted_bandwidth", rows)
    return rows


def fig17_unsched_prios(full: bool = False):
    """W1: slowdown vs number of unscheduled priority levels (1 sched)."""
    from repro.core.workloads import sample_sizes
    from repro.core.priorities import allocate_priorities
    levels = [1, 2, 4, 7] if full else [1, 2, 7]
    sizes = sample_sizes("W1", 20_000, np.random.default_rng(0))
    pts = []
    for nu in levels:
        al = allocate_priorities(sizes, unsched_limit=9728, force_unsched=nu)
        pts.append(dict(workload="W1", load=0.8,
                        alloc={"n_unsched": nu, "cutoffs": list(al.cutoffs)}))
    rows = []
    for nu, r in zip(levels, sim_sweep(pts, protocol="homa", overcommit=1)):
        rows.append(dict(n_unsched=nu, p99_small=r["p99_small"],
                         p99_all=r["p99_all"], p50_all=r["p50_all"]))
    emit("fig17_unsched_prios", rows)
    return rows


def fig18_cutoffs(full: bool = False):
    """W3, 2 unscheduled levels: sweep the cutoff point."""
    cutoffs = [200, 1000, 1930, 4000, 8000] if full else [200, 1930, 8000]
    pts = [dict(workload="W3", load=0.8,
                alloc={"n_unsched": 2, "cutoffs": [c]}) for c in cutoffs]
    rows = []
    for cutoff, r in zip(cutoffs, sim_sweep(pts, protocol="homa")):
        rows.append(dict(cutoff=cutoff, p99_small=r["p99_small"],
                         p99_all=r["p99_all"]))
    emit("fig18_cutoffs", rows)
    return rows


def fig19_sched_prios(full: bool = False):
    """W4: slowdown + sustainable load vs number of scheduled priorities
    (1 unscheduled level). Overcommit is a compile-time parameter, so each
    point is its own sim_run."""
    rows = []
    for k in ([1, 2, 4, 7] if full else [1, 4, 7]):
        r = sim_run(workload="W4", protocol="homa", load=0.8, overcommit=k,
                    alloc={"n_unsched": 1, "cutoffs": []})
        rows.append(dict(n_sched=k, p99_all=r["p99_all"],
                         completion=round(r["completion_rate"], 3),
                         wasted_frac=round(r["wasted_frac"], 4)))
    emit("fig19_sched_prios", rows)
    return rows


def fig20_unsched_bytes(full: bool = False):
    """W4: slowdown vs per-message unscheduled byte limit."""
    uls = [1000, 4864, 9728, 19456] if full else [1000, 9728, 19456]
    pts = [dict(workload="W4", load=0.8, unsched_limit_bytes=ul)
           for ul in uls]
    rows = []
    for ul, r in zip(uls, sim_sweep(pts, protocol="homa")):
        rows.append(dict(unsched_limit=ul, p99_small=r["p99_small"],
                         p99_all=r["p99_all"]))
    emit("fig20_unsched_bytes", rows)
    return rows


def fig21_prio_usage(full: bool = False):
    """W3: bytes per priority level at different loads."""
    loads = [0.5, 0.8, 0.9] if full else [0.5, 0.8]
    pts = [dict(workload="W3", load=ld) for ld in loads]
    rows = []
    for pt, r in zip(pts, sim_sweep(pts, protocol="homa")):
        total = max(sum(r["prio_drained_bytes"]), 1)
        for p, b in enumerate(r["prio_drained_bytes"]):
            rows.append(dict(load=pt["load"], prio=p, bytes=b,
                             frac=round(b / total, 4)))
    emit("fig21_prio_usage", rows)
    return rows


def table1_queues(full: bool = False):
    """TOR->host queue occupancy per workload at 80% load (the simulator
    models downlink queues; core queues are folded into the fixed delay,
    Table 1 shows they are tiny)."""
    workloads = ["W1", "W2", "W3", "W4", "W5"] if full \
        else ["W1", "W3", "W5"]
    pts = [dict(workload=w, load=0.8) for w in workloads]
    rows = []
    for w, r in zip(workloads, sim_sweep(pts, protocol="homa")):
        rows.append(dict(workload=w,
                         q_mean_kb=round(r["q_mean_bytes"] / 1e3, 1),
                         q_max_kb=round(r["q_max_bytes"] / 1e3, 1),
                         lost=r["lost_chunks"]))
    emit("table1_queues", rows)
    return rows


def fig10_incast(full: bool = False):
    """Incast: N concurrent ~RTTbytes responses to one receiver, with and
    without the incast-control unscheduled limit. Both variants of each N
    share one ``run_sweep`` trace (per-table unsched limits)."""
    from repro.core.sim import SimConfig, SweepSpec, run_sweep
    from repro.core.workloads import MessageTable
    rows = []
    for n in ([50, 150, 400, 1000] if full else [50, 300]):
        nh = 8
        src = (np.arange(n) % (nh - 1) + 1).astype(np.int32)
        tbl = MessageTable(src, np.zeros(n, np.int32),
                           np.full(n, 9728, np.int64),
                           np.zeros(n, np.int32), "incast", 0.0, 256)
        cfg = SimConfig(n_hosts=nh, protocol="homa",
                        max_slots=min(n * 60 + 4000, 120_000),
                        ring_cap=1024)
        res = run_sweep(cfg, SweepSpec(tables=[tbl, tbl],
                                       unsched_limit_bytes=[None, 512]))
        for control, stats in zip((False, True), res):
            done = stats.done
            tput = (stats.size_bytes[done].sum() * 8 /
                    ((stats.completion[done].max() + 1) * 256 * 0.8)
                    if done.any() else 0)   # Gbps at 10G line rate
            rows.append(dict(n_rpcs=n, incast_control=control,
                             completed=int(done.sum()),
                             lost_chunks=stats.lost_chunks,
                             q_max_kb=round(float(
                                 stats.q_max_bytes.max()) / 1e3, 1),
                             rel_throughput=round(float(tput) / 10, 3)))
    emit("fig10_incast", rows)
    return rows


def fig14_preemption_lag(full: bool = False):
    """The paper attributes Homa's residual tail to link-level preemption
    lag. The slotted model reproduces this structurally: finer slots =
    finer-grained link preemption. Sweep slot size; the short-message tail
    should shrink as preemption granularity improves. (Slot size changes
    the compile-time config, so these stay individual sim_runs.)"""
    rows = []
    for slot in ([1538, 512, 256, 128] if full else [1538, 256]):
        r = sim_run(workload="W3", protocol="homa", load=0.8,
                    slot_bytes=slot, n_messages=1200)
        rows.append(dict(slot_bytes=slot, p99_small=r["p99_small"],
                         p50_small=r["p50_small"]))
    emit("fig14_preemption_lag", rows)
    return rows
