"""Shared benchmark machinery: cached simulator runs + CSV emission.

All paper-figure benchmarks run the JAX packet-level simulator at reduced
scale (CPU budget): 8 hosts instead of 144, ~2000 messages per run. The
qualitative claims being validated (protocol ordering, slowdown bands,
utilization ceilings, queue bounds) are scale-robust; EXPERIMENTS.md
discusses the deltas. `--full` increases scale.

Two entry points, both returning the same JSON-safe summary schema
(:meth:`repro.core.SimResult.summary` plus the run's parameters):

  ``sim_run``    one cached point (legacy path, still used where points
                 differ in compile-time config such as slot size)
  ``sim_sweep``  a list of points sharing the protocol/topology config,
                 batched through ``run_sweep`` so the whole group costs
                 one jit trace instead of one per point
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from pathlib import Path

import numpy as np

from repro.core.sim import SimConfig, simulate, run_sweep
from repro.core.sweep import SweepSpec
from repro.core.fabric import FabricConfig
from repro.core.hostmodel import HostConfig
from repro.core.workloads import WorkloadSpec, make_messages
from repro.core import scenarios
from repro.core.priorities import PriorityAllocation

ART = Path(__file__).resolve().parents[1] / "artifacts" / "bench"
ART.mkdir(parents=True, exist_ok=True)

DEFAULT = dict(n_hosts=8, n_messages=2000, max_slots=60_000, ring_cap=2048,
               slot_bytes=256)


def _merge_params(n_hosts, n_messages, max_slots, ring_cap, slot_bytes,
                  fabric=None):
    p = {**DEFAULT, "fabric": fabric}
    for k, v in dict(n_hosts=n_hosts, n_messages=n_messages,
                     max_slots=max_slots, ring_cap=ring_cap,
                     slot_bytes=slot_bytes).items():
        if v is not None:
            p[k] = v
    return p


def _fabric_cfg(fabric: dict | None) -> FabricConfig | None:
    """JSON-able fabric spec (the cache-key form) -> FabricConfig."""
    return FabricConfig(**fabric) if fabric else None


def _host_key(host) -> str | dict | None:
    """Host spec -> its JSON-able cache-key form (preset name, kwargs
    dict, or a full HostConfig flattened to kwargs)."""
    if isinstance(host, HostConfig):
        return dataclasses.asdict(host)
    return host


def _spec_key(spec) -> dict | None:
    """WorkloadSpec (or its kwargs dict) -> JSON-able cache-key form."""
    if spec is None:
        return None
    if isinstance(spec, WorkloadSpec):
        spec = dataclasses.asdict(spec)
    return {k: (list(v) if isinstance(v, tuple) else v)
            for k, v in spec.items()}


def _point_table(pt: dict, p: dict):
    """Synthesize one point's MessageTable: a Poisson workload point
    (``workload`` + ``load``), a structured scenario (``scenario`` =
    {"kind": "incast" | "hotspot" | "shuffle", ...kwargs}), or a full
    ``spec`` (:class:`WorkloadSpec` instance or its kwargs dict) —
    the unified form the other two reduce to."""
    sp = pt.get("spec")
    if sp is not None:
        if any(k in pt for k in ("workload", "load", "scenario")):
            raise ValueError(
                "a sweep point combines 'spec' with 'workload'/'load'/"
                "'scenario'; a WorkloadSpec already carries the whole "
                "generation recipe — pass exactly one form")
        if not isinstance(sp, WorkloadSpec):
            sp = WorkloadSpec(**sp)
        if "seed" in pt:
            sp = sp.with_seed(pt["seed"])
        return sp.build(n_hosts=p["n_hosts"], slot_bytes=p["slot_bytes"])
    sc = pt.get("scenario")
    if sc is not None and ("workload" in pt or "load" in pt):
        raise ValueError(
            "a sweep point combines 'scenario' with 'workload'/'load', but "
            "scenario points ignore those fields — they would enter the "
            "cache key and masquerade as distinct data points; put "
            "background traffic inside the scenario spec instead")
    if sc is None:
        return make_messages(pt["workload"], n_hosts=p["n_hosts"],
                             load=pt["load"], n_messages=p["n_messages"],
                             slot_bytes=p["slot_bytes"],
                             seed=pt.get("seed", 0))
    sc = dict(sc)
    kind = sc.pop("kind")
    common = dict(n_hosts=p["n_hosts"], slot_bytes=p["slot_bytes"],
                  seed=pt.get("seed", 0))
    # a spec may spell seed (etc.) inside the scenario dict itself —
    # those win over the point/topology defaults, never collide
    common.update({k: sc.pop(k) for k in ("n_hosts", "slot_bytes", "seed")
                   if k in sc})
    if kind == "incast":
        return scenarios.incast(sc.pop("fan_in"), sc.pop("burst_bytes"),
                                **common, **sc)
    if kind == "hotspot":
        return scenarios.hotspot(sc.pop("workload"), **common, **sc)
    if kind == "shuffle":
        return scenarios.shuffle(**common, **sc)
    raise ValueError(f"unknown scenario kind {kind!r}; expected "
                     f"incast | hotspot | shuffle")


def _point_key(*, workload, protocol, load, seed, overcommit, alloc,
               unsched_limit_bytes, params, scenario=None, spec=None,
               host=None) -> tuple[dict, Path]:
    keyd = dict(workload=workload, protocol=protocol, load=load, seed=seed,
                overcommit=overcommit, alloc=alloc, scenario=scenario,
                ul=(unsched_limit_bytes if not isinstance(
                    unsched_limit_bytes, np.ndarray) else "array"), **params)
    # optional axes join the key ONLY when set, so every pre-existing
    # cache file and committed baseline `params` dict keeps its hash
    if spec is not None:
        keyd["spec"] = _spec_key(spec)
    if host is not None:
        keyd["host"] = _host_key(host)
    h = hashlib.sha1(json.dumps(keyd, sort_keys=True).encode()).hexdigest()[:16]
    return keyd, ART / f"sim_{h}.json"


def _alloc_from_dict(alloc: dict | None) -> PriorityAllocation | None:
    if not alloc:
        return None
    return PriorityAllocation(n_prios=alloc.get("n_prios", 8),
                              n_unsched=alloc["n_unsched"],
                              cutoffs=tuple(alloc.get("cutoffs", ())),
                              unsched_bytes_frac=0.0)


def _summarize(result, keyd) -> dict:
    return {"params": keyd, **result.summary(warmup_frac=0.1)}


def sim_run(*, workload: str, protocol: str, load: float, seed: int = 0,
            n_hosts=None, n_messages=None, max_slots=None, ring_cap=None,
            slot_bytes=None, overcommit=None, alloc: dict | None = None,
            unsched_limit_bytes=None, fabric: dict | None = None,
            host: dict | str | None = None, cache: bool = True) -> dict:
    """Run (or fetch cached) one simulation; returns JSON-safe summary.
    ``fabric`` is a JSON-able FabricConfig kwargs dict (cache-key form);
    ``host`` a preset name or HostConfig kwargs dict (DESIGN.md §10)."""
    p = _merge_params(n_hosts, n_messages, max_slots, ring_cap, slot_bytes,
                      fabric)
    keyd, fp = _point_key(workload=workload, protocol=protocol, load=load,
                          seed=seed, overcommit=overcommit, alloc=alloc,
                          unsched_limit_bytes=unsched_limit_bytes, params=p,
                          host=host)
    if cache and fp.exists():
        return json.loads(fp.read_text())

    tbl = make_messages(workload, n_hosts=p["n_hosts"], load=load,
                        n_messages=p["n_messages"],
                        slot_bytes=p["slot_bytes"], seed=seed)
    cfg = SimConfig(n_hosts=p["n_hosts"], slot_bytes=p["slot_bytes"],
                    protocol=protocol, overcommit=overcommit,
                    ring_cap=p["ring_cap"], fabric=_fabric_cfg(fabric),
                    host=host,
                    max_slots=min(p["max_slots"],
                                  int(tbl.arrival_slot.max()) + 20_000))
    res = simulate(cfg, tbl, alloc=_alloc_from_dict(alloc),
                   unsched_limit_bytes=unsched_limit_bytes)
    out = _summarize(res, keyd)
    fp.write_text(json.dumps(out))
    return out


def sim_sweep(points: list[dict], *, protocol: str, overcommit=None,
              n_hosts=None, n_messages=None, max_slots=None, ring_cap=None,
              slot_bytes=None, fabric: dict | None = None,
              host: dict | str | None = None,
              cache: bool = True) -> list[dict]:
    """Cached batched runner: each point is a dict with ``workload`` and
    ``load`` (or a ``scenario``/``spec`` form, see :func:`_point_table`)
    plus optional ``seed`` / ``alloc`` / ``unsched_limit_bytes``. All
    points share the protocol/topology config — including the optional
    leaf-spine ``fabric`` spec (a FabricConfig kwargs dict) and ``host``
    model (preset name or HostConfig kwargs dict); uncached
    points run through ``run_sweep(cfg, SweepSpec(...))``, which groups
    runs by their static scan parameters internally (one jit trace per
    group — scenario sweeps legitimately vary the message count).
    Returns one summary per point, in order.

    Cache keys use the *configured* ``max_slots`` cap (exactly like
    ``sim_run``), never the realized group horizon, so a point's cache
    identity does not depend on which other points share its sweep and
    fully-cached reruns skip table synthesis entirely. Uncached points
    run at a shared horizon — the longest uncached table's, clamped to
    the cap — recorded in the stored summary as ``max_slots_used``."""
    p = _merge_params(n_hosts, n_messages, max_slots, ring_cap, slot_bytes,
                      fabric)
    keys = [_point_key(workload=pt.get("workload"), protocol=protocol,
                       load=pt.get("load"), seed=pt.get("seed", 0),
                       overcommit=overcommit, alloc=pt.get("alloc"),
                       unsched_limit_bytes=pt.get("unsched_limit_bytes"),
                       scenario=pt.get("scenario"), spec=pt.get("spec"),
                       host=host, params=p)
            for pt in points]
    out: list[dict | None] = [None] * len(points)
    todo = []
    for i, (keyd, fp) in enumerate(keys):
        if cache and fp.exists():
            out[i] = json.loads(fp.read_text())
        else:
            todo.append(i)
    if todo:
        tables = {i: _point_table(points[i], p) for i in todo}
        horizon = max(int(t.arrival_slot.max()) for t in tables.values())
        ms = min(p["max_slots"], horizon + 20_000)
        cfg = SimConfig(n_hosts=p["n_hosts"], slot_bytes=p["slot_bytes"],
                        protocol=protocol, overcommit=overcommit,
                        ring_cap=p["ring_cap"], fabric=_fabric_cfg(fabric),
                        host=host, max_slots=ms)
        # mixed table lengths are fine: run_sweep groups runs by their
        # static scan parameters internally (core/sweep.group_runs — the
        # same grouping this function used to reimplement)
        spec = SweepSpec(
            tables=[tables[i] for i in todo],
            alloc=[_alloc_from_dict(points[i].get("alloc")) for i in todo],
            unsched_limit_bytes=[points[i].get("unsched_limit_bytes")
                                 for i in todo])
        for i, res in zip(todo, run_sweep(cfg, spec)):
            keyd, fp = keys[i]
            out[i] = {**_summarize(res, keyd), "max_slots_used": ms}
            fp.write_text(json.dumps(out[i]))
    return out


def emit(name: str, rows: list[dict]):
    """Print CSV rows and save them under artifacts/bench/<name>.json."""
    if not rows:
        print(f"# {name}: no rows")
        return
    cols = list(rows[0].keys())
    print(f"# --- {name} ---")
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r.get(c, "")) for c in cols))
    (ART / f"{name}.json").write_text(json.dumps(rows, indent=1))
