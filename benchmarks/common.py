"""Shared benchmark machinery: cached simulator runs + CSV emission.

All paper-figure benchmarks run the JAX packet-level simulator at reduced
scale (CPU budget): 8 hosts instead of 144, ~2000 messages per run. The
qualitative claims being validated (protocol ordering, slowdown bands,
utilization ceilings, queue bounds) are scale-robust; EXPERIMENTS.md
discusses the deltas. `--full` increases scale.
"""
from __future__ import annotations

import hashlib
import json
from pathlib import Path

import numpy as np

from repro.core.sim import SimConfig, run_sim, slowdown_percentiles
from repro.core.workloads import make_messages
from repro.core.priorities import allocate_priorities, PriorityAllocation

ART = Path(__file__).resolve().parents[1] / "artifacts" / "bench"
ART.mkdir(parents=True, exist_ok=True)

DEFAULT = dict(n_hosts=8, n_messages=2000, max_slots=60_000, ring_cap=2048,
               slot_bytes=256)


def sim_run(*, workload: str, protocol: str, load: float, seed: int = 0,
            n_hosts=None, n_messages=None, max_slots=None, ring_cap=None,
            slot_bytes=None, overcommit=None, alloc: dict | None = None,
            unsched_limit_bytes=None, cache: bool = True) -> dict:
    """Run (or fetch cached) one simulation; returns JSON-safe summary."""
    p = {**DEFAULT}
    for k, v in dict(n_hosts=n_hosts, n_messages=n_messages,
                     max_slots=max_slots, ring_cap=ring_cap,
                     slot_bytes=slot_bytes).items():
        if v is not None:
            p[k] = v
    keyd = dict(workload=workload, protocol=protocol, load=load, seed=seed,
                overcommit=overcommit, alloc=alloc,
                ul=(unsched_limit_bytes if not isinstance(
                    unsched_limit_bytes, np.ndarray) else "array"), **p)
    h = hashlib.sha1(json.dumps(keyd, sort_keys=True).encode()).hexdigest()[:16]
    fp = ART / f"sim_{h}.json"
    if cache and fp.exists():
        return json.loads(fp.read_text())

    tbl = make_messages(workload, n_hosts=p["n_hosts"], load=load,
                        n_messages=p["n_messages"],
                        slot_bytes=p["slot_bytes"], seed=seed)
    cfg = SimConfig(n_hosts=p["n_hosts"], slot_bytes=p["slot_bytes"],
                    protocol=protocol, overcommit=overcommit,
                    ring_cap=p["ring_cap"],
                    max_slots=min(p["max_slots"],
                                  int(tbl.arrival_slot.max()) + 20_000))
    al = None
    if alloc:
        al = PriorityAllocation(n_prios=alloc.get("n_prios", 8),
                                n_unsched=alloc["n_unsched"],
                                cutoffs=tuple(alloc.get("cutoffs", ())),
                                unsched_bytes_frac=0.0)
    stats = run_sim(cfg, tbl, alloc=al,
                    unsched_limit_bytes=unsched_limit_bytes)

    # summarize (steady-state window: drop first 10% of arrivals)
    warm = stats["size_bytes"].shape[0] // 10
    ok = stats["done"].copy()
    ok[:warm] = False
    sl = stats["slowdown"]
    out = {
        "params": keyd,
        "n_complete": stats["n_complete"],
        "n_messages": stats["n_messages"],
        "completion_rate": float(stats["done"].mean()),
        "p99_by_size": slowdown_percentiles(
            {**stats, "done": ok}, 99.0),
        "busy_frac": float(np.mean(stats["busy_frac"])),
        "wasted_frac": float(np.mean(stats["wasted_frac"])),
        "q_mean_bytes": float(np.mean(stats["q_mean_bytes"])),
        "q_max_bytes": float(np.max(stats["q_max_bytes"])),
        "prio_drained_bytes": [int(x) for x in stats["prio_drained_bytes"]],
        "lost_chunks": stats["lost_chunks"],
        "alloc": {"n_unsched": stats["alloc"].n_unsched,
                  "cutoffs": list(stats["alloc"].cutoffs),
                  "unsched_frac": stats["alloc"].unsched_bytes_frac},
        "p99_small": _pct(sl, ok & (stats["size_bytes"] < 1000), 99),
        "p50_small": _pct(sl, ok & (stats["size_bytes"] < 1000), 50),
        "p99_all": _pct(sl, ok, 99),
        "p50_all": _pct(sl, ok, 50),
    }
    fp.write_text(json.dumps(out))
    return out


def _pct(sl, mask, q):
    if mask.sum() == 0:
        return None
    return float(np.percentile(sl[mask], q))


def emit(name: str, rows: list[dict]):
    """Print CSV rows and save them under artifacts/bench/<name>.json."""
    if not rows:
        print(f"# {name}: no rows")
        return
    cols = list(rows[0].keys())
    print(f"# --- {name} ---")
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r.get(c, "")) for c in cols))
    (ART / f"{name}.json").write_text(json.dumps(rows, indent=1))
