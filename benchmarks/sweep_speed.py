"""Sweep-runner speed cells: batched-vs-sequential plus the sharded
mega-grid (DESIGN.md §9).

Two cells, both emitted into ``sweep_speed.json``:

**batch** — the original acceptance check: one Python-loop ``simulate``
call per seed (per-point config, per-point jit trace) vs one
``run_sweep(cfg, SweepSpec(...))`` batching the same 8 seeds behind a
single trace. Criterion: ratio < 0.5 on the 8-seed homa sweep.

**mega** — the paper-scale grid shape (ISSUE 8 acceptance): 6 protocols
x 3 loads x 4 seeds = 72 runs, sharded over every visible device
(``shard=True``) with chunked scans and streaming accumulators, so only
O(buckets) per run returns to the host. Reports ``n_devices``,
``mega_s`` and the throughput figure ``runs_per_sec_per_device`` that
``check_regression.py`` gates; the per-protocol streaming p99s are
bit-deterministic (integer histograms, identical across device counts)
and gate exactly. Run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to exercise the
multi-device path on CPU (the CI multi-device leg does).
"""
from __future__ import annotations

import time

from benchmarks.common import emit

N_SEEDS = 8
MEGA_PROTOS = ("homa", "basic", "phost", "pias", "pfabric", "ndp")
MEGA_LOADS = (0.5, 0.7, 0.9)
MEGA_SEEDS = (0, 1, 2, 3)


def _batch_cell(full: bool, workload: str, load: float,
                n_messages: int | None, protocol: str) -> dict:
    from repro.core.sim import SimConfig, simulate, run_sweep
    from repro.core.sweep import SweepSpec
    from repro.core.workloads import make_messages

    n_messages = n_messages or (1000 if full else 300)
    margin = 2000 if full else 600
    tables = [make_messages(workload, n_hosts=8, load=load,
                            n_messages=n_messages, slot_bytes=256, seed=s)
              for s in range(N_SEEDS)]

    # legacy: per-point config -> per-point trace (what paper_figs.py did
    # for every point before sim_sweep existed)
    t0 = time.perf_counter()
    seq = []
    for t in tables:
        cfg = SimConfig(n_hosts=8, protocol=protocol, ring_cap=256,
                        max_slots=int(t.arrival_slot.max()) + margin)
        seq.append(simulate(cfg, t))
    seq_s = time.perf_counter() - t0

    horizon = max(int(t.arrival_slot.max()) for t in tables) + margin
    cfg = SimConfig(n_hosts=8, protocol=protocol, ring_cap=256,
                    max_slots=horizon)
    t0 = time.perf_counter()
    res = run_sweep(cfg, SweepSpec(tables=tables, shared_alloc=True))
    sweep_s = time.perf_counter() - t0

    return dict(kind="batch", protocol=protocol, workload=workload,
                load=load, n_seeds=N_SEEDS, n_messages=n_messages,
                sequential_s=round(seq_s, 3), sweep_s=round(sweep_s, 3),
                ratio=round(sweep_s / seq_s, 3),
                seq_complete=sum(r.n_complete for r in seq),
                sweep_complete=sum(r.n_complete for r in res))


def _mega_cell(full: bool, workload: str) -> dict:
    import jax
    from repro.core.sim import SimConfig, run_sweep
    from repro.core.sweep import SweepSpec
    from repro.core.workloads import make_messages

    n_messages = 400 if full else 150
    n_dev = len(jax.devices())
    tables = [make_messages(workload, n_hosts=8, load=ld,
                            n_messages=n_messages, slot_bytes=256, seed=s)
              for ld in MEGA_LOADS for s in MEGA_SEEDS]
    horizon = max(int(t.arrival_slot.max()) for t in tables) \
        + (2000 if full else 600)
    spec = SweepSpec(tables=tables, shared_alloc=True, shard=True,
                     chunk_slots=512, streaming=True)

    row = dict(kind="mega", workload=workload, n_messages=n_messages,
               n_protocols=len(MEGA_PROTOS), n_loads=len(MEGA_LOADS),
               n_seeds=len(MEGA_SEEDS))
    t0 = time.perf_counter()
    completions = 0
    for proto in MEGA_PROTOS:
        cfg = SimConfig(n_hosts=8, protocol=proto, ring_cap=256,
                        max_slots=horizon)
        stats = run_sweep(cfg, spec)
        completions += sum(s.n_complete for s in stats)
        # pooled streaming p99 across the protocol's 12 runs: integer
        # histograms sum exactly, so this gates bit-exactly across
        # device counts in check_regression.py
        pooled = sum(s.hist.sum(axis=0) for s in stats)
        from repro.core.sweep import percentile_from_hist
        row[f"p99_{proto}"] = round(
            percentile_from_hist(pooled, stats[0].stream, 99.0), 4)
    mega_s = time.perf_counter() - t0

    n_runs = len(MEGA_PROTOS) * len(tables)
    row.update(n_runs=n_runs, n_devices=n_dev, mega_s=round(mega_s, 3),
               runs_per_sec_per_device=round(mega_s and
                                             n_runs / mega_s / n_dev, 3),
               completions=completions)
    return row


def sweep_speed(full: bool = False, *, workload: str = "W1",
                load: float = 0.8, n_messages: int | None = None,
                protocol: str = "homa"):
    rows = [_batch_cell(full, workload, load, n_messages, protocol),
            _mega_cell(full, workload)]
    emit("sweep_speed", rows)
    return rows
