"""run_sweep vs sequential run_sim: the batched-runner acceptance check.

Replays the legacy benchmark pattern — one Python-loop ``run_sim`` call
per (protocol, workload, load, seed) point, each with its own per-point
``max_slots`` and therefore its own jit trace — against ``run_sweep``,
which stacks the same 8 seeds behind ONE jit trace (shared horizon,
shared workload-level priority allocation).

Emits ``sweep_speed.json`` with both wall times; the acceptance criterion
is ratio < 0.5 on an 8-seed homa sweep.
"""
from __future__ import annotations

import time

from benchmarks.common import emit

N_SEEDS = 8


def sweep_speed(full: bool = False, *, workload: str = "W1",
                load: float = 0.8, n_messages: int | None = None,
                protocol: str = "homa"):
    from repro.core.sim import SimConfig, run_sim, run_sweep
    from repro.core.workloads import make_messages

    n_messages = n_messages or (1000 if full else 300)
    margin = 2000 if full else 600
    tables = [make_messages(workload, n_hosts=8, load=load,
                            n_messages=n_messages, slot_bytes=256, seed=s)
              for s in range(N_SEEDS)]

    # legacy: per-point config -> per-point trace (what paper_figs.py did
    # for every point before sim_sweep existed)
    t0 = time.perf_counter()
    seq = []
    for t in tables:
        cfg = SimConfig(n_hosts=8, protocol=protocol, ring_cap=256,
                        max_slots=int(t.arrival_slot.max()) + margin)
        seq.append(run_sim(cfg, t))
    seq_s = time.perf_counter() - t0

    horizon = max(int(t.arrival_slot.max()) for t in tables) + margin
    cfg = SimConfig(n_hosts=8, protocol=protocol, ring_cap=256,
                    max_slots=horizon)
    t0 = time.perf_counter()
    res = run_sweep(cfg, tables, shared_alloc=True)
    sweep_s = time.perf_counter() - t0

    rows = [dict(protocol=protocol, workload=workload, load=load,
                 n_seeds=N_SEEDS, n_messages=n_messages,
                 sequential_s=round(seq_s, 3), sweep_s=round(sweep_s, 3),
                 ratio=round(sweep_s / seq_s, 3),
                 seq_complete=sum(r["n_complete"] for r in seq),
                 sweep_complete=sum(r.n_complete for r in res))]
    emit("sweep_speed", rows)
    return rows
