"""Homa-scheduled gradient sync vs fused/naive sync — two complementary
views (DESIGN.md §2.2 adaptation):

1. **Structural** (HLO): build the DP train step with homa vs naive sync on
   8 host devices; count collectives and their sizes from the compiled HLO —
   message-orientation means many small collectives instead of a few huge
   ones, and the K-lane barrier chains bound concurrent in-flight bytes.

2. **Predicted wall-time** (simulator): feed the actual gradient chunk trace
   of a model into the packet-level simulator as a Homa message workload on
   the pod interconnect, with a straggler sender injected; compare sync
   completion time homa vs basic. This reuses the paper's own machinery to
   predict the benefit of its scheduling on collective traffic.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit


def structural(full: bool = False):
    import subprocess
    import sys
    import os
    import textwrap
    from pathlib import Path
    repo = Path(__file__).resolve().parents[1]
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, json
        from jax.sharding import PartitionSpec as P
        from repro.distrib import homa_collectives as HC
        mesh = jax.make_mesh((8,), ("data",))
        from repro.configs.reduced import reduced_config
        from repro.models import model as M
        from repro.models.params import init_params
        cfg = reduced_config("llama3.2-3b")
        params = init_params(M.model_defs(cfg), jax.random.key(0))
        grads = jax.tree.map(lambda p: p.astype(jnp.float32), params)

        for name, scfg in [
            ("homa", HC.SyncConfig(chunk_bytes=1 << 14, overcommit=7)),
            ("homa_int8", HC.SyncConfig(chunk_bytes=1 << 14, overcommit=7,
                                        compress="int8")),
        ]:
            @jax.shard_map(mesh=mesh, in_specs=(P(),), out_specs=P(),
                           check_vma=False)
            def sync(g):
                out, _ = HC.homa_allreduce(g, "data", scfg)
                return out

            txt = jax.jit(sync).lower(grads).compile().as_text()
            nar = txt.count(" all-reduce(") + txt.count(" all-reduce-start(")
            nag = txt.count(" all-gather(") + txt.count(" all-gather-start(")
            print(json.dumps({"mode": name, "all_reduce": nar,
                              "all_gather": nag}))

        @jax.shard_map(mesh=mesh, in_specs=(P(),), out_specs=P(),
                       check_vma=False)
        def naive(g):
            return HC.naive_allreduce(g, "data")
        txt = jax.jit(naive).lower(grads).compile().as_text()
        print(json.dumps({"mode": "naive",
                          "all_reduce": txt.count(" all-reduce(")
                          + txt.count(" all-reduce-start("),
                          "all_gather": txt.count(" all-gather(")}))
    """)
    env = {**os.environ, "PYTHONPATH": str(repo / "src"),
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=repo, timeout=900)
    rows = []
    import json as _json
    for line in r.stdout.splitlines():
        line = line.strip()
        if line.startswith("{"):
            rows.append(_json.loads(line))
    if r.returncode != 0:
        rows.append({"mode": "ERROR", "all_reduce": -1,
                     "all_gather": r.stderr[-200:]})
    emit("collective_structural", rows)
    return rows


def predicted(full: bool = False):
    """Simulator-predicted sync behaviour: gradient chunks as Homa messages.

    Measured finding (see EXPERIMENTS): with the simulator's Homa-style
    senders, small-tensor latency stays at slowdown ~1.0 even UNCHUNKED —
    because sender-side SRPT already reorders small tensors ahead of large
    ones. This confirms the paper's §2.2 claim ("senders need SRPT also")
    from the gradient-sync angle: the HoL catastrophe of streaming syncs
    comes from in-order senders, and either chunking (message orientation)
    or sender SRPT removes it. The makespan itself is bandwidth+straggler
    bound and schedule-invariant, as expected."""
    from repro.core.sim import SimConfig, simulate
    from repro.core.workloads import MessageTable
    from repro.distrib.homa_collectives import SyncConfig, chunk_plan
    from repro.configs.reduced import reduced_config
    from repro.models import model as M
    from repro.models.params import param_shapes, tree_map_defs
    import jax

    cfg = reduced_config("llama3.2-3b")
    shapes = [(tuple(s.shape), s.dtype) for s in
              jax.tree.leaves(param_shapes(M.model_defs(cfg)))]
    rows = []
    # A/B: message orientation. chunked = Homa-style size-bounded messages;
    # unchunked = streaming-style whole-tensor messages (the paper's
    # InfRC/TCP single-stream analogue) — the big-tensor messages HoL-block
    # the small ones. (With uniform chunk sizes SRPT-vs-FIFO is a no-op by
    # construction — measured and expected; size diversity is what makes
    # scheduling matter, which is the paper's own premise.)
    for chunked in (True, False):
        # streaming mode sends tensors in definition order (embedding first,
        # like a naive fused/streaming sync); chunked mode uses the Homa
        # SRPT plan
        plan = chunk_plan(shapes, SyncConfig(
            chunk_bytes=(1 << 13) if chunked else (1 << 30), srpt=chunked))
        n_hosts = 8
        # all-gather-style exchange: chunk i of host h goes to peer
        # (h+1+i) % H, so receiver downlinks are contended (multiple senders
        # per destination) and the issue ORDER (srpt vs fifo) is the
        # messages' arrival order. Host 0 is a straggler (sends 3000 slots
        # late) — Homa's overcommitment must keep the other downlinks busy.
        msgs = len(plan) * n_hosts
        src = np.repeat(np.arange(n_hosts), len(plan)).astype(np.int32)
        ci = np.tile(np.arange(len(plan)), n_hosts)
        dst = ((src + 1 + ci % (n_hosts - 1)) % n_hosts).astype(np.int32)
        size = np.tile([c.bytes for c in plan], n_hosts).astype(np.int64)
        # arrival order = the scheduler's issue order (2 slots per issue)
        arr = (ci * 2).astype(np.int32)
        arr[src == 0] += 3000                      # straggler
        tbl = MessageTable(src, dst, size, arr, "gradsync", 0.0, 256)
        for proto in ("homa", "basic"):
            sim = SimConfig(n_hosts=n_hosts, protocol=proto,
                            max_slots=40_000, ring_cap=4096)
            st = simulate(sim, tbl)
            done = st.done
            fin = int(st.completion[done].max()) if done.any() else -1
            # the makespan is bandwidth+straggler-bound for ANY schedule;
            # what scheduling buys is EARLY completions (first tensors
            # unblock overlapped optimizer updates) and small-message
            # latency (the paper's whole point):
            comp = np.sort(st.completion[done])
            half = int(comp[len(comp) // 2]) if len(comp) else -1
            small = done & (st.size_bytes < 2048)
            p99s = (st.percentile(99, small) or -1 if small.any() else -1)
            rows.append(dict(mode="chunked" if chunked else "unchunked",
                             protocol=proto,
                             all_done=bool(done.all()),
                             sync_slots=fin,
                             half_done_slot=half,
                             small_chunk_p99_slowdown=round(p99s, 2)))
    emit("collective_predicted", rows)
    return rows
