"""Benchmark orchestrator: one harness per paper table/figure + the
framework-side benchmarks. Prints ``name,us_per_call,derived`` CSV blocks
(per-figure CSVs are emitted by each harness; this prints a roll-up).

    PYTHONPATH=src python -m benchmarks.run [--full] [--only figNN,...]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sweeps (slow)")
    ap.add_argument("--only", default=None,
                    help="comma-separated harness names")
    args = ap.parse_args()

    from benchmarks import paper_figs as F
    from benchmarks import collective_sched as C
    from benchmarks import fabric_figs as FF
    from benchmarks import faults_figs as FL
    from benchmarks import hostmodel_figs as HM
    from benchmarks import telemetry_figs as TF
    from benchmarks.roofline import backend_compare, fused_speed
    from benchmarks.sweep_speed import sweep_speed

    harnesses = {
        "sweep_speed": sweep_speed,
        "backend_compare": backend_compare,
        "fused_speed": fused_speed,
        "fabric_smoke": FF.fabric_smoke,
        "fabric_oversub": FF.fabric_oversub,
        "fig14_fabric_incast": FF.fig14_fabric_incast,
        "faults_smoke": FL.faults_smoke,
        "fig_faults": FL.fig_faults,
        "hostmodel_smoke": HM.hostmodel_smoke,
        "fig_hostmodel": HM.fig_hostmodel,
        "trace_smoke": TF.trace_smoke,
        "fig13_prio_usage_time": TF.fig13_prio_usage_time,
        "fig10_incast": F.fig10_incast,
        "fig12_slowdown": F.fig12_slowdown,
        "fig13_median": F.fig13_median,
        "fig14_preemption_lag": F.fig14_preemption_lag,
        "fig15_utilization": F.fig15_utilization,
        "fig16_wasted_bandwidth": F.fig16_wasted_bandwidth,
        "fig17_unsched_prios": F.fig17_unsched_prios,
        "fig18_cutoffs": F.fig18_cutoffs,
        "fig19_sched_prios": F.fig19_sched_prios,
        "fig20_unsched_bytes": F.fig20_unsched_bytes,
        "fig21_prio_usage": F.fig21_prio_usage,
        "table1_queues": F.table1_queues,
        "collective_structural": C.structural,
        "collective_predicted": C.predicted,
    }
    only = set(args.only.split(",")) if args.only else None

    summary = []
    for name, fn in harnesses.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            rows = fn(full=args.full)
            dt = time.time() - t0
            summary.append((name, dt * 1e6 / max(len(rows), 1),
                            f"rows={len(rows)}"))
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            summary.append((name, -1, "ERROR"))

    print("\n# --- roll-up: name,us_per_call,derived ---")
    for name, us, derived in summary:
        print(f"{name},{us:.0f},{derived}")
    if any(d == "ERROR" for _, _, d in summary):
        sys.exit(1)


if __name__ == "__main__":
    main()
