"""Telemetry benchmarks (DESIGN.md §8).

Two harnesses:

  ``trace_smoke``           the CI cell: one fig_faults-scale lossy
                            leaf-spine homa run, simulated with tracing
                            off and on. Pins the captured ledger/series
                            shape exactly (event counts, overflow,
                            samples, exported Perfetto event count) and
                            reports the measured capture overhead (wall
                            fields, ratio-gated) plus the AOT
                            trace/compile/execute split. Also exercises
                            the ``SimResult.to_json(full=True)`` /
                            ``from_json`` round-trip as the bench-cache
                            full-result store, and exports a sample
                            Perfetto trace under ``artifacts/bench/``
                            (uploaded as a CI artifact).
  ``fig13_prio_usage_time`` the paper's Fig. 13 priority-usage view
                            unrolled over time: per-window drained bytes
                            per priority level from the strided series,
                            for homa on W2 — shows the receiver walking
                            its scheduled levels as load shifts.

Capture-overhead target (ISSUE 7): < 20% slot-rate regression with
tracing on at the default stride. The measured value is a wall field —
reported and carried in the baseline, never exact-gated.
"""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import ART, emit
from repro.core import SimConfig, FabricConfig, TraceConfig, simulate, \
    make_messages
from repro.core.results import SimResult

TOPO = dict(n_hosts=16, racks=4, oversub=2.0, ring_cap=1024, up_cap=2048)


def _smoke_cfg(trace: TraceConfig | None, max_slots: int) -> SimConfig:
    fab = FabricConfig(racks=TOPO["racks"], oversub=TOPO["oversub"],
                       up_cap=TOPO["up_cap"],
                       faults=dict(up_loss=0.01))
    return SimConfig(n_hosts=TOPO["n_hosts"], protocol="homa",
                     ring_cap=TOPO["ring_cap"], max_slots=max_slots,
                     fabric=fab, trace=trace)


def trace_smoke(full: bool = False):
    """One lossy leaf-spine homa run, traced vs untraced (the CI cell)."""
    n_msgs, max_slots = (1200, 30_000) if full else (400, 8_000)
    tbl = make_messages("W2", n_hosts=TOPO["n_hosts"], load=0.5,
                        n_messages=n_msgs, slot_bytes=256, seed=7)

    # untraced leg: capture disabled (bit-identical to trace=None) but
    # wallclock on, so both legs report the exact AOT execute time of
    # their scan — the slot-rate comparison is free of jit-dispatch and
    # warmup noise
    cfg_off = _smoke_cfg(TraceConfig(enabled=False, wallclock=True,
                                     wallclock_repeats=3), max_slots)
    r_off = simulate(cfg_off, tbl)
    t_off = r_off.trace_summary["timings"]

    # traced leg at the default stride, same protocol physics
    cfg_on = _smoke_cfg(TraceConfig(stride=16, ledger_cap=4096,
                                    wallclock=True, wallclock_repeats=3),
                        max_slots)
    r_on = simulate(cfg_on, tbl)
    timings = r_on.trace.timings
    overhead = (timings["execute_s"] - t_off["execute_s"]) \
        / t_off["execute_s"] * 100 if t_off["execute_s"] > 0 else 0.0

    # tracing must be pure observation (a real error, not an assert:
    # must survive `python -O`)
    if not np.array_equal(r_off.completion, r_on.completion):
        raise RuntimeError("tracing changed completion slots")

    # bench-cache full-result round-trip (SimResult.from_json satellite)
    full_fp = ART / "trace_smoke_full.json"
    full_fp.write_text(r_on.to_json(full=True))
    r_back = SimResult.from_json(full_fp.read_text())
    if not np.array_equal(r_back.completion, r_on.completion):
        raise RuntimeError("SimResult JSON round-trip drifted")

    # sample exporter outputs (CI uploads artifacts/bench/*)
    tr = r_on.trace
    doc = tr.to_perfetto(ART / "trace_sample_perfetto.json")
    json.loads((ART / "trace_sample_perfetto.json").read_text())  # valid?
    (ART / "trace_sample_timeseries.json").write_text(
        json.dumps(tr.to_timeseries_json()))

    rows = [dict(
        protocol="homa", n_messages=n_msgs, slots=max_slots,
        n_complete=r_back.n_complete,
        n_events=tr.n_events, n_events_seen=tr.n_events_seen,
        events_dropped=tr.events_dropped, samples=len(tr.sample_slots),
        stride=tr.stride, perfetto_events=len(doc["traceEvents"]),
        exec_off_s=round(t_off["execute_s"], 3),
        exec_on_s=round(timings["execute_s"], 3),
        overhead_pct=round(overhead, 1),
        aot_trace_s=timings["trace_s"], aot_compile_s=timings["compile_s"],
        aot_execute_s=timings["execute_s"])]
    emit("trace_smoke", rows)
    print(f"# trace_smoke capture overhead: {overhead:.1f}% "
          f"(target < 20%)")
    return rows


def fig13_prio_usage_time(full: bool = False):
    """Priority usage over time (paper Fig. 13, unrolled): per-window
    drained bytes per priority level from the strided trace series."""
    n_msgs, max_slots = (2000, 40_000) if full else (600, 10_000)
    tbl = make_messages("W2", n_hosts=8, load=0.7, n_messages=n_msgs,
                        slot_bytes=256, seed=0)
    cfg = SimConfig(n_hosts=8, protocol="homa", ring_cap=1024,
                    max_slots=max_slots,
                    trace=TraceConfig(stride=max_slots // 40,
                                      ledger_cap=0))
    r = simulate(cfg, tbl)
    usage = r.trace.prio_usage("down")              # (T, P) bytes
    tot = usage.sum(axis=1, keepdims=True)
    share = np.where(tot > 0, usage / np.maximum(tot, 1), 0.0)
    rows = []
    for k, t in enumerate(r.trace.sample_slots.tolist()):
        row = dict(slot=int(t), drained_bytes=int(usage[k].sum()))
        row.update({f"p{p}_share": round(float(share[k, p]), 3)
                    for p in range(usage.shape[1])})
        rows.append(row)
    emit("fig13_prio_usage_time", rows)
    return rows
