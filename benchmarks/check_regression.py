"""Bench-smoke regression gate (CI satellite).

Compares the benchmark summaries a CI run just wrote under
``artifacts/bench/`` against the baselines committed in
``benchmarks/baselines/`` and FAILS on drift, instead of only uploading
artifacts for a human to eyeball:

- deterministic fields (completions, losses, queue depths, slowdown
  percentiles — everything the simulator computes) must match EXACTLY:
  the simulator is seeded and bit-reproducible, so any drift is a
  behaviour change that must be reviewed and re-baselined on purpose;
- wall-time fields (``sweep_speed``'s timings) only gate within a
  generous multiplicative factor — machine speed is not a regression.

    PYTHONPATH=src python -m benchmarks.check_regression            # gate
    PYTHONPATH=src python -m benchmarks.check_regression --update   # rebase
    PYTHONPATH=src python -m benchmarks.check_regression --only faults_smoke

``--update`` copies the current artifacts over the baselines; commit the
result together with whatever change legitimately moved the numbers.
``--only name[,name...]`` restricts both modes to a subset of harnesses
(used by the CI backend matrix, which runs only the faults cell).
"""
from __future__ import annotations

import json
import shutil
import sys
from pathlib import Path

ART = Path(__file__).resolve().parents[1] / "artifacts" / "bench"
BASE = Path(__file__).resolve().parent / "baselines"

# harness -> {field: max allowed ratio vs baseline}; every field not
# listed gates on exact equality. Harnesses not listed here are not
# gated at all (e.g. backend_compare: pure timing).
WALL_FIELDS = {
    "fig10_incast": {},
    "fabric_smoke": {},
    "faults_smoke": {},
    "hostmodel_smoke": {},
    # telemetry CI cell: capture shape (event counts, overflow, samples,
    # perfetto size) gates exactly; wall times and the derived overhead
    # percentage only within a generous factor (machine speed / noise —
    # overhead_pct compares small differences of small numbers)
    "trace_smoke": {"exec_off_s": 25.0, "exec_on_s": 25.0,
                    "overhead_pct": 1000.0, "aot_trace_s": 25.0,
                    "aot_compile_s": 25.0, "aot_execute_s": 25.0},
    # sweep_speed: wall times and the runs/sec/device throughput figure
    # gate within a factor; n_devices gates as a ratio too (the CI
    # multi-device leg runs the same cell on 8 virtual devices against a
    # 1-device baseline). The streaming per-protocol p99s and completion
    # counts are integer-histogram-deterministic across device counts
    # and chunk sizes, so they gate exactly.
    "sweep_speed": {"sequential_s": 25.0, "sweep_s": 25.0, "ratio": 25.0,
                    "mega_s": 25.0, "runs_per_sec_per_device": 25.0,
                    "n_devices": 32.0},
    # fused mega-kernel cell (DESIGN.md §11): completion count/checksum
    # and the staged==fused bitmatch flag gate exactly; wall times and
    # the derived speedup only within a factor (interpret-mode CPU
    # timing is launch/trace overhead, not the kernel win)
    "fused_speed": {"staged_s": 25.0, "fused_s": 25.0, "speedup": 25.0},
}


def _wall_ok(a, b, factor: float) -> bool:
    try:
        a, b = float(a), float(b)
    except (TypeError, ValueError):
        return a == b
    if a <= 0 or b <= 0:
        return True          # degenerate timings: don't gate on them
    return max(a / b, b / a) <= factor


def check_harness(name: str) -> list[str]:
    wall = WALL_FIELDS[name]
    got_fp, want_fp = ART / f"{name}.json", BASE / f"{name}.json"
    if not want_fp.exists():
        return [f"{name}: no committed baseline {want_fp} — run with "
                f"--update and commit it"]
    if not got_fp.exists():
        return [f"{name}: {got_fp} missing — did the benchmark run?"]
    want = json.loads(want_fp.read_text())
    got = json.loads(got_fp.read_text())
    if len(got) != len(want):
        return [f"{name}: row count {len(got)} != baseline {len(want)}"]
    errs = []
    for i, (g, w) in enumerate(zip(got, want)):
        for field in sorted(set(g) | set(w)):
            gv, wv = g.get(field), w.get(field)
            if field in wall:
                if not _wall_ok(gv, wv, wall[field]):
                    errs.append(f"{name}[{i}].{field}: {gv} vs baseline "
                                f"{wv} (beyond {wall[field]}x)")
            elif gv != wv:
                errs.append(f"{name}[{i}].{field}: {gv!r} != baseline "
                            f"{wv!r}")
    return errs


def main() -> int:
    args = sys.argv[1:]
    names = list(WALL_FIELDS)
    if "--only" in args:
        only = set(args[args.index("--only") + 1].split(","))
        unknown = only - set(WALL_FIELDS)
        if unknown:
            print(f"unknown harness(es) {sorted(unknown)}; gated: "
                  f"{names}")
            return 2
        names = [n for n in names if n in only]
    if "--update" in args:
        BASE.mkdir(exist_ok=True)
        for name in names:
            fp = ART / f"{name}.json"
            if not fp.exists():
                print(f"skip {name}: {fp} missing (run the benchmark "
                      f"first)")
                continue
            shutil.copy(fp, BASE / f"{name}.json")
            print(f"baselined {BASE / f'{name}.json'}")
        return 0
    errors = [e for name in names for e in check_harness(name)]
    for e in errors:
        print(f"REGRESSION: {e}")
    if not errors:
        print(f"bench gate OK ({', '.join(names)})")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
